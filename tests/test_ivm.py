"""Streaming island + delta-driven materialized views (ISSUE 9).

The contract under test: after ``append``-ing rows to a streaming
registration, a warm serve that patches its materialized view through the
derived ``deltaplan.UpdatePlan`` must be *indistinguishable* from a full
recompute — identical values, shapes and valid counts — across every
provably-incremental operator family (the 200-example differential
property); anything unprovable must fall back to recompute and still be
correct, never wrong.  Around that core: the STREAM qlang block compiles to
the same signatures as the programmatic build, views persist and patch
across process restarts, the pricing gate recomputes when the delta
dominates (``"force"`` overrides it), breaker state survives ``persist()``
(satellite 2), the incremental scatter gather folds frames in any arrival
order (satellite 1), and the merge-on-save protocol never resurrects a
``@!``-masked plan-cache entry under multi-process contention
(satellite 4).
"""
import multiprocessing
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.proptest import given, settings, strategies as st

from repro.core import deltaplan, tables
from repro.core.health import CLOSED, OPEN, EngineHealth
from repro.core.islands import array, relational, stream
from repro.core.ioutil import load_json
from repro.core.middleware import (MASK_SEP, BigDAWG, default_health_path,
                                   default_view_cache_path, masked_sig)
from repro.core.monitor import Monitor
from repro.core.ops import Ref
from repro.core.procpool import (IncrementalGather, ProcPool,
                                 _plan_cache_hammer)
from repro.core.qlang import bigdawg as qparse
from repro.core.signature import signature
from repro.core.tables import ColumnarTable, DenseTensor, StreamBuffer

# bounded shape buckets keep the jit cache small across 200+ examples
_BASE_ROWS = (8, 12, 16)
_DELTA_ROWS = (2, 4)
_COLS = 4


def _dense(rng, rows):
    return DenseTensor(rng.normal(size=(rows, _COLS)).astype(np.float32))


def _col(rng, rows):
    return ColumnarTable({
        "key": rng.integers(0, 6, rows).astype(np.int32),
        "value": rng.normal(size=rows).astype(np.float32)})


def _stream(rng, rows, t0=0.0):
    return StreamBuffer(rng.normal(size=(rows, _COLS)).astype(np.float32),
                        t0=t0)


def _bd(incremental, state=None, **kw):
    kw.setdefault("train_plans", 1)
    kw.setdefault("train_repeats", 1)
    return BigDAWG(monitor=Monitor(state, shared=bool(state)),
                   incremental=incremental, **kw)


# Each family: (maker kind, static side tables, query builder, whether the
# delta lineage is provably incremental).  Unprovable families MUST still
# serve correct results via full recompute (Report.incremental False).
_STATIC_W = "W"          # (COLS, 3) dense — replicated matmul operand
_STATIC_A0 = "A0"        # (6, COLS) dense — concat's untouched first input
_STATIC_R = "R"          # 6-key columnar — replicated join right side

FAMILIES = [
    ("dense_scale", "dense",
     lambda: array.scale(Ref("S"), factor=2.0), True),
    ("dense_select", "dense",
     lambda: array.select(Ref("S"), lo=-0.5, hi=0.5), True),
    ("dense_matmul_left", "dense",
     lambda: array.matmul(Ref("S"), Ref(_STATIC_W)), True),
    ("dense_add_self", "dense",
     lambda: array.add(Ref("S"), Ref("S")), True),
    ("dense_haar", "dense",
     lambda: array.haar(Ref("S"), levels=1), True),
    ("dense_count_of_select", "dense",
     lambda: array.count(array.select(Ref("S"), lo=0.0)), True),
    ("dense_concat_last", "dense",
     lambda: array.concat(Ref(_STATIC_A0), Ref("S")), True),
    ("dense_transpose", "dense",
     lambda: array.transpose(Ref("S")), False),
    ("dense_tfidf", "dense",
     lambda: array.tfidf(Ref("S")), False),
    ("dense_concat_first", "dense",
     lambda: array.concat(Ref("S"), Ref(_STATIC_A0)), False),
    ("col_select", "columnar",
     lambda: relational.select(Ref("S"), column="value", lo=0.0), True),
    ("col_project", "columnar",
     lambda: relational.project(Ref("S"), columns=["value"]), True),
    ("col_count", "columnar",
     lambda: relational.count(Ref("S")), True),
    ("col_sort", "columnar",
     lambda: relational.sort(Ref("S"), by="value"), True),
    ("col_groupby_sum", "columnar",
     lambda: relational.groupby_sum(Ref("S"), key="key", value="value",
                                    num_groups=6), True),
    ("col_join_left", "columnar",
     lambda: relational.join(Ref("S"), Ref(_STATIC_R),
                             left_on="key", right_on="key"), True),
    ("col_join_right", "columnar",
     lambda: relational.join(Ref(_STATIC_R), Ref("S"),
                             left_on="key", right_on="key"), False),
    ("col_distinct", "columnar",
     lambda: relational.distinct(Ref("S"), column="value"), False),
    ("stream_haar", "stream",
     lambda: stream.haar(Ref("S"), levels=1), True),
]

_ENGINE_OF = {"dense": "dense_array", "columnar": "columnar",
              "stream": "stream"}
_MAKER_OF = {"dense": _dense, "columnar": _col, "stream": _stream}


def _register_statics(bd, rng):
    bd.register(_STATIC_W, DenseTensor(
        rng.normal(size=(_COLS, 3)).astype(np.float32)), "dense_array")
    bd.register(_STATIC_A0, _dense(rng, 6), "dense_array")
    bd.register(_STATIC_R, ColumnarTable({
        "key": np.arange(6, dtype=np.int32),
        "rval": rng.normal(size=6).astype(np.float32)}), "columnar")


def _assert_equal(a, b):
    a, b = tables.host_copy(a), tables.host_copy(b)
    assert type(a) is type(b)
    if isinstance(a, DenseTensor):
        assert np.asarray(a.data).shape == np.asarray(b.data).shape
        np.testing.assert_allclose(np.asarray(a.data, np.float64),
                                   np.asarray(b.data, np.float64),
                                   rtol=1e-5, atol=1e-5)
        assert a.valid_count == b.valid_count
    elif isinstance(a, ColumnarTable):
        assert set(a.columns) == set(b.columns)
        av, bv = np.asarray(a.valid), np.asarray(b.valid)
        assert np.array_equal(av, bv)
        for c in a.columns:
            np.testing.assert_allclose(
                np.asarray(a.columns[c], np.float64)[av],
                np.asarray(b.columns[c], np.float64)[bv],
                rtol=1e-5, atol=1e-5)
    elif isinstance(a, StreamBuffer):
        assert np.asarray(a.data).shape == np.asarray(b.data).shape
        np.testing.assert_allclose(np.asarray(a.data, np.float64),
                                   np.asarray(b.data, np.float64),
                                   rtol=1e-5, atol=1e-5)
        assert a.t0 == b.t0
    else:
        raise AssertionError(f"unexpected container {type(a).__name__}")


# ---------------------------------------------------------------------------
# the 200-example differential property: delta patch == full recompute
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=len(FAMILIES) - 1),
       st.sampled_from(_BASE_ROWS), st.sampled_from(_DELTA_ROWS),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_delta_serve_equals_full_recompute(fi, nb, nd, seed):
    tag, kind, build, provable = FAMILIES[fi]
    rng = np.random.default_rng(seed)
    maker, engine = _MAKER_OF[kind], _ENGINE_OF[kind]
    base, delta = maker(rng, nb), maker(rng, nd)

    bd = _bd(incremental="force")
    _register_statics(bd, np.random.default_rng(7))
    bd.register("S", base, engine, streaming=True)
    q = build()
    bd.execute(q, mode="training")          # materializes the view
    assert bd.append("S", delta) == 1
    rep = bd.execute(q, mode="production")
    assert rep.incremental == provable, (tag, rep.incremental)
    if provable:
        assert bd.ivm_serves == 1 and bd.ivm_fallbacks == 0
    else:
        assert bd.ivm_serves == 0 and bd.ivm_fallbacks == 1

    oracle = _bd(incremental=False)
    _register_statics(oracle, np.random.default_rng(7))
    oracle.register("S", tables.append_rows(base, delta), engine,
                    streaming=True)
    full = oracle.execute(q, mode="training")
    assert full.incremental is False
    _assert_equal(rep.result, full.result)

    # the patched view keeps serving: a second append must patch again (or
    # fall back again), and still match a from-scratch recompute
    if provable:
        delta2 = maker(rng, nd)
        bd.append("S", delta2)
        rep2 = bd.execute(q, mode="production")
        assert rep2.incremental and bd.ivm_serves == 2
        oracle2 = _bd(incremental=False)
        _register_statics(oracle2, np.random.default_rng(7))
        oracle2.register(
            "S", tables.append_rows(tables.append_rows(base, delta), delta2),
            engine, streaming=True)
        _assert_equal(rep2.result,
                      oracle2.execute(q, mode="training").result)


def test_unchanged_view_serves_verbatim():
    rng = np.random.default_rng(3)
    bd = _bd(incremental="force")
    bd.register("S", _dense(rng, 12), "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=3.0)
    r0 = bd.execute(q, mode="training")
    r1 = bd.execute(q, mode="production")   # no appends: view verbatim
    assert r1.incremental and r1.cache_hit
    _assert_equal(r0.result, r1.result)
    assert bd.ivm_serves == 1


def test_reregister_bumps_epoch_and_drops_view():
    """Replacing a streaming registration outright (same name, same row
    count) must invalidate the view — content identity is the epoch, not
    the row count."""
    rng = np.random.default_rng(4)
    bd = _bd(incremental="force")
    bd.register("S", _dense(rng, 12), "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=2.0)
    bd.execute(q, mode="training")
    fresh = _dense(rng, 12)
    bd.register("S", fresh, "dense_array", streaming=True)
    rep = bd.execute(q, mode="production")
    assert rep.incremental is False
    np.testing.assert_allclose(np.asarray(tables.host_copy(rep.result).data),
                               np.asarray(fresh.data) * 2.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# STREAM qlang block: same signatures, same incremental serves
# ---------------------------------------------------------------------------

def test_stream_qlang_block_compiles_to_same_signature():
    rng = np.random.default_rng(5)
    bd = _bd(incremental="force")
    bd.register("S", _stream(rng, 12), "stream", streaming=True)
    q_prog = stream.haar(Ref("S"), levels=1)
    q_text = qparse("BIGDAWG(STREAM(haar(S, levels=1)))")
    assert signature(q_text, bd.catalog) == signature(q_prog, bd.catalog)
    bd.execute(q_text, mode="training")
    bd.append("S", _stream(rng, 4, t0=12.0))
    rep = bd.execute(q_text, mode="production")
    assert rep.incremental
    oracle = _bd(incremental=False)
    oracle.register("S", bd.catalog["S"].obj, "stream", streaming=True)
    _assert_equal(rep.result,
                  oracle.execute(q_prog, mode="training").result)


def test_streaming_signature_is_shape_free():
    """Appends must not move the signature — that is what keeps the plan
    cache and view keyed stably across appends."""
    rng = np.random.default_rng(6)
    bd = _bd(incremental=True)
    bd.register("S", _dense(rng, 8), "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=2.0)
    before = signature(q, bd.catalog)
    bd.append("S", _dense(rng, 4))
    assert signature(q, bd.catalog) == before


# ---------------------------------------------------------------------------
# the pricing gate: incremental only when the cost model says it pays
# ---------------------------------------------------------------------------

def test_gate_recomputes_when_delta_dominates_and_force_overrides():
    rng = np.random.default_rng(8)

    def serve_after_big_append(mode):
        bd = _bd(incremental=mode)
        bd.register("S", _dense(rng, 8), "dense_array", streaming=True)
        q = array.matmul(Ref("S"), Ref("W"))
        bd.register("W", DenseTensor(
            rng.normal(size=(_COLS, 3)).astype(np.float32)), "dense_array")
        bd.execute(q, mode="training")
        bd.append("S", _dense(rng, 512))    # delta >> base: patching can't
        rep = bd.execute(q, mode="production")  # beat recomputing
        return bd, rep

    bd, rep = serve_after_big_append(True)
    assert rep.incremental is False and bd.ivm_fallbacks == 1
    bd, rep = serve_after_big_append("force")
    assert rep.incremental is True and bd.ivm_serves == 1


def test_incremental_off_never_materializes():
    rng = np.random.default_rng(9)
    bd = _bd(incremental=False)
    bd.register("S", _dense(rng, 12), "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=2.0)
    bd.execute(q, mode="training")
    bd.append("S", _dense(rng, 2))
    rep = bd.execute(q, mode="production")
    assert rep.incremental is False
    assert bd.ivm_serves == 0 and bd.ivm_fallbacks == 0
    entry = bd.plan_cache[rep.sig]
    assert entry.view is None


# ---------------------------------------------------------------------------
# registration / append validation
# ---------------------------------------------------------------------------

def test_streaming_registration_validation():
    rng = np.random.default_rng(10)
    bd = _bd(incremental=True)
    with pytest.raises(ValueError):      # casts are not append-preserving
        bd.register("S", _dense(rng, 8), "columnar", streaming=True)
    with pytest.raises(ValueError):      # sharding + appends don't compose
        bd.register("S", _dense(rng, 8), "dense_array", shards=2,
                    streaming=True)
    with pytest.raises(TypeError):       # 0-d: no row dimension to grow
        bd.register("Z", DenseTensor(np.float32(3.0)), "dense_array",
                    streaming=True)
    bd.register("P", _dense(rng, 8), "dense_array")          # not streaming
    with pytest.raises(ValueError):
        bd.append("P", _dense(rng, 2))
    with pytest.raises(KeyError):
        bd.append("missing", _dense(rng, 2))
    bd.register("S", _dense(rng, 8), "dense_array", streaming=True)
    with pytest.raises((TypeError, ValueError)):             # kind mismatch
        bd.append("S", _col(rng, 2))


# ---------------------------------------------------------------------------
# view persistence: patch across a process restart
# ---------------------------------------------------------------------------

def test_views_persist_and_patch_after_restart(tmp_path):
    state = str(tmp_path / "mon.json")
    rng = np.random.default_rng(11)
    base, delta = _dense(rng, 12), _dense(rng, 4)

    bd1 = _bd(incremental="force", state=state)
    bd1.register("S", base, "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=2.0)
    bd1.execute(q, mode="training")
    bd1.persist()
    assert os.path.exists(default_view_cache_path(state))

    # "restarted process": same state paths, data re-registered already
    # grown (the deployment contract: registrations replay current tables)
    bd2 = _bd(incremental="force", state=state)
    bd2.register("S", base, "dense_array", streaming=True)
    bd2.append("S", delta)
    rep = bd2.execute(q, mode="production")
    assert rep.incremental, "restored view did not patch"
    full = tables.append_rows(base, delta)
    np.testing.assert_allclose(np.asarray(tables.host_copy(rep.result).data),
                               np.asarray(full.data) * 2.0, rtol=1e-5)


def test_view_save_skips_masked_and_oversized(tmp_path):
    from repro.core import middleware as mw
    state = str(tmp_path / "mon.json")
    rng = np.random.default_rng(12)
    bd = _bd(incremental="force", state=state)
    bd.register("S", _dense(rng, 12), "dense_array", streaming=True)
    q = array.scale(Ref("S"), factor=2.0)
    rep = bd.execute(q, mode="training")
    # graft a masked entry carrying a view: it must never hit the file
    entry = bd.plan_cache[rep.sig]
    bad = masked_sig(rep.sig, frozenset({"kv_sparse"}))
    bd.plan_cache[bad] = entry
    bd.save_views()
    blob = load_json(default_view_cache_path(state))
    assert list(blob["entries"]) == [rep.sig]
    # oversized views stay memory-only
    old = mw.VIEW_PERSIST_MAX_BYTES
    mw.VIEW_PERSIST_MAX_BYTES = 1
    try:
        bd.save_views(merge=False)
        assert load_json(default_view_cache_path(state))["entries"] == {}
    finally:
        mw.VIEW_PERSIST_MAX_BYTES = old


# ---------------------------------------------------------------------------
# satellite 2: breaker state persists and restores
# ---------------------------------------------------------------------------

def test_breaker_snapshot_restore_semantics():
    h = EngineHealth(failure_threshold=1)
    h.record_failure("kv_sparse")        # trips OPEN
    h.record_success("columnar")
    snap = h.snapshot()
    assert snap["kv_sparse"]["state"] == OPEN
    h2 = EngineHealth(failure_threshold=1)
    h2.restore(snap)
    s2 = h2.snapshot()
    assert s2["kv_sparse"]["state"] == OPEN
    assert s2["kv_sparse"]["trips"] == 1
    assert s2["columnar"]["state"] == CLOSED
    # malformed entries are skipped, not fatal
    h2.restore({"weird": "not-a-dict", "also": {"state": "bogus"}})


def test_health_persists_across_restart(tmp_path):
    state = str(tmp_path / "mon.json")
    rng = np.random.default_rng(13)
    bd1 = _bd(incremental=True, state=state,
              health=EngineHealth(failure_threshold=1))
    bd1.register("X", _dense(rng, 8), "dense_array")
    bd1.health.record_failure("kv_sparse")
    bd1.persist()
    assert os.path.exists(default_health_path(state))

    bd2 = _bd(incremental=True, state=state,
              health=EngineHealth(failure_threshold=1))
    snap = bd2.health.snapshot()
    assert snap["kv_sparse"]["state"] == OPEN     # outage knowledge kept
    assert snap["kv_sparse"]["trips"] == 1
    # a health-less middleware ignores the file entirely
    bd3 = _bd(incremental=True, state=state)
    assert bd3.health is None


# ---------------------------------------------------------------------------
# satellite 1: incremental gather folds frames in any arrival order
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.sampled_from(["concat", "sum", "kmerge"]),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_incremental_gather_matches_batch_merge(merge, n, seed):
    rng = np.random.default_rng(seed)
    if merge == "concat":
        parts = [_dense(rng, int(rng.integers(1, 5))) for _ in range(n)]
        oracle = tables.concat_shards(parts)
    elif merge == "sum":
        parts = [ColumnarTable({"key": np.arange(4, dtype=np.int32),
                                "sum": rng.normal(size=4)})
                 for _ in range(n)]
        oracle = tables.sum_shards(parts)
    else:
        parts = [ColumnarTable({
            "key": np.sort(rng.integers(0, 40, 5)).astype(np.int32),
            "value": rng.normal(size=5).astype(np.float32)})
            for _ in range(n)]
        oracle = tables.kmerge_shards(parts, "key")
    order = rng.permutation(n)
    g = IncrementalGather(merge, n, by="key" if merge == "kmerge" else None)
    for i in order:
        g.add(int(i), parts[i])
    out = g.result()
    if merge == "kmerge":
        for c in ("key", "value"):
            np.testing.assert_allclose(np.asarray(out.columns[c]),
                                       np.asarray(oracle.columns[c]))
    else:
        _assert_equal(out, oracle)
    assert g.folds == n - 1


def test_incremental_gather_guards():
    with pytest.raises(ValueError):
        IncrementalGather("median", 2)
    g = IncrementalGather("concat", 3)
    g.add(2, _dense(np.random.default_rng(0), 2))   # out of order: pending
    with pytest.raises(RuntimeError):
        g.result()


# ---------------------------------------------------------------------------
# streaming appends across a worker pool
# ---------------------------------------------------------------------------

def test_pool_append_reaches_every_worker_and_respawn(tmp_path):
    rng = np.random.default_rng(14)
    base, delta = _dense(rng, 12), _dense(rng, 4)
    state = str(tmp_path / "mon.json")
    with ProcPool(2, state_path=state, train_plans=1) as pool:
        pool.register("S", base, "dense_array", streaming=True)
        pool.register("P", base, "dense_array")
        with pytest.raises(ValueError):
            pool.register("T", base, "dense_array", shards=2, streaming=True)
        with pytest.raises(ValueError):
            pool.append("P", delta)          # not a streaming registration
        with pytest.raises(KeyError):
            pool.append("missing", delta)
        q = array.scale(Ref("S"), factor=2.0)
        pool.execute(q, mode="training")
        assert pool.append("S", delta) == 1
        full = tables.append_rows(base, delta)
        # both workers serve the grown table (round-robin hits each)
        for _ in range(2):
            rep = pool.execute(q, mode="production")
            np.testing.assert_allclose(
                np.asarray(tables.host_copy(rep.result).data),
                np.asarray(full.data) * 2.0, rtol=1e-5)
        # a killed worker replays the grown table, not the pre-append base
        pool.workers[0].proc.terminate()
        pool.workers[0].proc.join(timeout=10)
        for _ in range(2):
            rep = pool.execute(q, mode="production")
            assert np.asarray(tables.host_copy(rep.result).data).shape == \
                np.asarray(full.data).shape
        assert pool.respawns >= 1


# ---------------------------------------------------------------------------
# satellite 4: masked signatures never survive multi-process merge-on-save
# ---------------------------------------------------------------------------

def test_masked_entries_never_resurrect_under_contention(tmp_path):
    """N real processes hammer one shared plan-cache file with merge-saves
    and reloads while a ``@!``-masked entry is repeatedly injected into the
    file underneath them.  Every private signature must survive; the masked
    one must be gone from the final file after any process's save, must
    never be adopted into a fresh load, and must never be re-persisted."""
    state = str(tmp_path / "contended.json")
    bad = masked_sig("deg-sig", frozenset({"kv_sparse"}))
    ctx = multiprocessing.get_context("spawn")
    n_procs, rounds = 3, 6
    procs = [ctx.Process(target=_plan_cache_hammer,
                         args=(state, f"private-{i}", bad, rounds, i))
             for i in range(n_procs)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    # a fresh process loads the survivors — and never the masked entry,
    # even if the last file write was an adversarial injection
    bd = BigDAWG(monitor=Monitor(state, shared=True))
    assert not any(MASK_SEP in sig for sig in bd.plan_cache)
    for i in range(n_procs):
        assert f"private-{i}" in bd.plan_cache
    bd.save_plan_cache()
    blob = load_json(bd.plan_cache_path)
    assert not any(MASK_SEP in sig for sig in blob["entries"])
    assert all(f"private-{i}" in blob["entries"] for i in range(n_procs))
