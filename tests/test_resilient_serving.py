"""Resilient serving (ISSUE 6): per-engine circuit breakers, failover
re-planning under an engine mask, the error taxonomy behind the async
Session API, adaptive latency-keyed shedding, and the qlang SQL surface.

Covers the tentpole's contract end to end: breaker state transitions
(closed -> open -> half-open probe -> closed), masked-DP agreement with the
exhaustive enumerator, an injected mid-serve outage that fails over with
ZERO failed requests, and recovery restoring the pre-failure incumbent plan
verbatim (masked serves never pollute the unmasked signature's history).
"""
import json
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, ColumnarTable, DenseTensor, array, connect,
                        relational, signature)
from repro.core.errors import (BigDAWGError, EngineDown, Overloaded,
                               PlanInfeasible, QueryParseError,
                               is_engine_failure)
from repro.core.health import (CLOSED, DEFAULT_ALWAYS_UP, HALF_OPEN, OPEN,
                               CircuitBreaker, EngineHealth)
from repro.core.middleware import MASK_SEP, _plan_from_key, masked_sig
from repro.core.planner import dp_plans, exhaustive_plans, node_candidates
from repro.core.qlang import bigdawg as parse_text
from repro.runtime.fault import EngineFaultInjector, SimulatedFailure
from repro.runtime.server import BatchServer, QueryServer, Request, Shed


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]
    return t, (lambda: t[0])


def _portable_query():
    """Every node has >= 2 candidate engines (haar: dense/columnar/stream,
    tfidf: dense/columnar/kv_sparse) — failover can always re-plan it."""
    return array.tfidf(array.haar("waves", levels=2))


def _resilient_session(threshold=2, cooldown=5.0, **kw):
    t, clock = _fake_clock()
    inj = EngineFaultInjector()
    health = EngineHealth(failure_threshold=threshold, cooldown_s=cooldown,
                          time_fn=clock, injector=inj)
    s = connect(health=health, train_plans=2, train_repeats=1, **kw)
    rng = np.random.default_rng(0)
    s.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(16, 64)).astype(np.float32))), "dense_array")
    s.register("T", ColumnarTable(
        {"v": rng.normal(size=32).astype(np.float32)}), "columnar")
    return s, health, inj, t


# ---------------------------------------------------------------------------
# (1) CircuitBreaker state machine
# ---------------------------------------------------------------------------

def test_breaker_trips_after_threshold_consecutive_failures():
    br = CircuitBreaker("kv_sparse", failure_threshold=3)
    assert br.on_failure(0.0) is False
    assert br.on_failure(0.0) is False
    assert br.state == CLOSED
    assert br.on_failure(1.0) is True          # third consecutive -> OPEN
    assert br.state == OPEN and br.trips == 1 and br.opened_at == 1.0


def test_breaker_success_resets_consecutive_run():
    br = CircuitBreaker("kv_sparse", failure_threshold=2)
    br.on_failure(0.0)
    br.on_success()                            # run broken: back to zero
    assert br.consecutive_failures == 0
    br.on_failure(0.0)
    assert br.state == CLOSED                  # 1 < threshold again


def test_breaker_cooldown_half_open_then_probe_success_closes():
    br = CircuitBreaker("stream", failure_threshold=1, cooldown_s=5.0)
    br.on_failure(0.0)
    assert br.poll(4.9) == OPEN                # cooldown not elapsed
    assert br.poll(5.0) == HALF_OPEN
    br.on_success()                            # the probe came back healthy
    assert br.state == CLOSED and br.trips == 1


def test_breaker_probe_failure_reopens_immediately():
    br = CircuitBreaker("stream", failure_threshold=3, cooldown_s=5.0)
    for _ in range(3):
        br.on_failure(0.0)
    br.poll(6.0)
    assert br.state == HALF_OPEN
    # ONE probe failure re-opens (no need to burn the threshold again) and
    # the cooldown restarts from now
    assert br.on_failure(6.0) is True
    assert br.state == OPEN and br.opened_at == 6.0 and br.trips == 2


# ---------------------------------------------------------------------------
# (2) EngineHealth registry: masks, probes, degrade, stragglers
# ---------------------------------------------------------------------------

def test_mask_grants_single_half_open_probe():
    t, clock = _fake_clock()
    h = EngineHealth(failure_threshold=1, cooldown_s=5.0, time_fn=clock)
    h.record_failure("kv_sparse")
    mask, probes = h.mask_for_request()
    assert "kv_sparse" in mask and probes == ()
    t[0] = 5.0                                  # cooldown elapses
    mask1, probes1 = h.mask_for_request()       # first request: the probe
    assert "kv_sparse" not in mask1 and probes1 == ("kv_sparse",)
    mask2, probes2 = h.mask_for_request()       # concurrent second request
    assert "kv_sparse" in mask2 and probes2 == ()
    h.release_probes(probes1)                   # plan never touched it
    _, probes3 = h.mask_for_request()
    assert probes3 == ("kv_sparse",)            # grantable again


def test_degrade_mask_spares_always_up_engines():
    h = EngineHealth()
    mask = h.degrade_mask()
    assert not mask & set(DEFAULT_ALWAYS_UP)
    assert mask == {"kv_sparse", "stream"}


def test_straggler_flag_counts_as_breaker_failure():
    t, clock = _fake_clock()
    h = EngineHealth(failure_threshold=1, straggler_z=3.0,
                     straggler_warmup=4, time_fn=clock)
    rng = np.random.default_rng(0)              # seeded: an unlucky global
    for _ in range(8):                          # stream can z-flag a warm-up
        h.after_plan([("stream", 0.010 + 0.001 * rng.random())])
    assert h.state("stream") == CLOSED
    h.after_plan([("stream", 10.0)])            # pathological straggler
    assert h.state("stream") == OPEN and h.trips() == 1


def test_straggler_floor_suppresses_jitter_flags():
    t, clock = _fake_clock()
    h = EngineHealth(failure_threshold=1, straggler_z=3.0,
                     straggler_warmup=4, straggler_min_s=0.05, time_fn=clock)
    for i in range(8):                          # small nonzero variance
        h.after_plan([("stream", 0.001 + 0.0001 * i)])
    h.after_plan([("stream", 0.010)])           # z-outlier, but sub-floor
    assert h.state("stream") == CLOSED
    h.after_plan([("stream", 10.0)])            # real pathological slowness
    assert h.state("stream") == OPEN


def test_snapshot_reports_states():
    h = EngineHealth(failure_threshold=1)
    h.record_failure("stream")
    snap = h.snapshot()
    assert snap["stream"]["state"] == OPEN and snap["stream"]["trips"] == 1
    assert snap["dense_array"]["state"] == CLOSED


# ---------------------------------------------------------------------------
# (3) masked planning
# ---------------------------------------------------------------------------

def test_node_candidates_mask_and_plan_infeasible():
    node = relational.select("T", column="v", lo=0.0)
    assert "columnar" in node_candidates(node)
    with pytest.raises(PlanInfeasible) as ei:
        node_candidates(node, mask=frozenset({"columnar"}))
    assert ei.value.op == "select" and "columnar" in ei.value.masked


def test_masked_dp_matches_exhaustive_and_avoids_engine():
    bd = BigDAWG(train_plans=2)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(16, 64)).astype(np.float32))), engine="dense_array")
    q = _portable_query()
    mask = frozenset({"dense_array"})
    ranked = dp_plans(q, bd.catalog, max_plans=4, cost_model=bd.cost_model,
                      mask=mask)
    exact = exhaustive_plans(q, bd.catalog, cost_model=bd.cost_model,
                             mask=mask)
    assert ranked[0][1].key == exact[0][1].key
    assert ranked[0][0] == pytest.approx(exact[0][0])
    for _, plan in ranked:
        assert all(eng != "dense_array" for _, eng in plan.assignment)


def test_masked_cache_entries_not_persisted(tmp_path):
    bd = BigDAWG()
    sig = "array.tfidf(array.haar(dense[8x6]))"
    mkey = masked_sig(sig, frozenset({"dense_array"}))
    assert mkey == sig + MASK_SEP + "dense_array"
    from repro.core.middleware import CachedPlan
    from repro.core.planner import Plan
    plan = Plan(((0, "columnar"), (1, "columnar")))
    bd.plan_cache[sig] = CachedPlan(plan)
    bd.plan_cache[mkey] = CachedPlan(plan)
    path = str(tmp_path / "plans.json")
    bd.save_plan_cache(path)
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert sig in entries and mkey not in entries


# ---------------------------------------------------------------------------
# (4) error taxonomy
# ---------------------------------------------------------------------------

def test_error_taxonomy_subclasses():
    for cls in (EngineDown, PlanInfeasible, Overloaded, QueryParseError):
        assert issubclass(cls, BigDAWGError)
    assert issubclass(QueryParseError, ValueError)   # pre-taxonomy contract
    e = EngineDown("kv_sparse", "tfidf", TimeoutError("t"))
    assert e.engine == "kv_sparse" and e.op == "tfidf"
    assert isinstance(e.cause, TimeoutError)
    p = PlanInfeasible("select", "relational", masked=("columnar",))
    assert p.island == "relational" and p.masked == ("columnar",)


def test_is_engine_failure_classification():
    assert is_engine_failure(TimeoutError())
    assert is_engine_failure(ConnectionError())
    assert is_engine_failure(SimulatedFailure("injected"))
    assert not is_engine_failure(KeyError("column"))
    assert not is_engine_failure(ValueError("bad query"))


def test_shed_alias_contract():
    # the pre-taxonomy name must keep working: construction, isinstance,
    # and the query/reason attributes the PR 5 tests rely on
    assert Shed is Overloaded
    r = Shed("q")
    assert isinstance(r, Overloaded) and isinstance(r, BigDAWGError)
    assert r.query == "q" and r.reason == "max_pending"
    assert r.status == "shed"


# ---------------------------------------------------------------------------
# (5) failover end to end: outage -> degraded serve -> recovery
# ---------------------------------------------------------------------------

def test_failover_and_recovery_restore_incumbent():
    s, health, inj, t = _resilient_session(threshold=2, cooldown=5.0)
    q = _portable_query()
    s.execute(q, mode="training")
    r_ok = s.execute(q)
    assert r_ok.mode == "production" and r_ok.status == "ok"
    assert not r_ok.degraded and r_ok.failovers == 0
    incumbent = r_ok.plan_key
    down = {eng for _, eng in _plan_from_key(incumbent).assignment}
    for eng in down:
        inj.fail_engine(eng)

    # outage: EngineDown retries burn the threshold, the breaker opens, and
    # the request is re-planned around the dead engine(s) — it still succeeds
    r_deg = s.execute(q)
    assert r_deg.status == "degraded" and r_deg.degraded
    assert r_deg.failovers >= 1
    deg_engines = {eng for _, eng in _plan_from_key(r_deg.plan_key).assignment}
    assert not deg_engines & down
    assert all(health.state(eng) == OPEN for eng in down)
    assert health.trips() == len(down)

    # second degraded request serves the mask-keyed cache entry: no DP, no
    # further failovers
    r_deg2 = s.execute(q)
    assert r_deg2.status == "degraded" and r_deg2.failovers == 0
    assert r_deg2.report.cache_hit and r_deg2.plan_key == r_deg.plan_key

    # recovery: cooldown elapses, the half-open probe request plans unmasked
    # and — because masked serves were recorded under the mask-suffixed
    # signature — the monitor still names the incumbent, restored verbatim
    for eng in down:
        inj.recover(eng)
    t[0] += 5.0
    r_rec = s.execute(q)
    assert r_rec.status == "ok" and not r_rec.degraded
    assert r_rec.plan_key == incumbent
    assert all(health.state(eng) == CLOSED for eng in down)
    assert health.trips() == len(down)          # no new trips on recovery
    assert s.bigdawg.failovers == r_deg.failovers


def test_query_error_propagates_raw_and_never_feeds_breaker():
    s, health, inj, t = _resilient_session()
    bad = relational.select("T")                # missing the column attr
    with pytest.raises(KeyError):               # NOT EngineDown
        s.execute(bad)
    assert health.state("columnar") == CLOSED and health.trips() == 0


def test_plan_infeasible_when_only_capable_engine_is_down():
    s, health, inj, t = _resilient_session(threshold=1)
    q = relational.select("T", column="v", lo=0.0)   # columnar-only op
    inj.fail_engine("columnar")
    with pytest.raises(PlanInfeasible):
        s.execute(q)
    assert health.state("columnar") == OPEN


def test_server_zero_failed_requests_under_injected_outage():
    s, health, inj, t = _resilient_session(threshold=2)
    q = _portable_query()
    srv = QueryServer(s.bigdawg)
    srv.warm([q])
    inj.fail_engine("dense_array")
    reports = srv.submit_many([_portable_query() for _ in range(6)],
                              workers=2)
    # zero failed requests: every slot is a served Report, none raised and
    # none were shed
    assert len(reports) == 6
    assert all(not isinstance(r, Overloaded) for r in reports)
    assert all(r.result is not None for r in reports)
    assert srv.stats["failovers"] >= 1
    assert srv.stats["breaker_trips"] >= 1
    assert srv.stats["degraded"] >= 1
    assert any(r.status == "degraded" for r in reports)


# ---------------------------------------------------------------------------
# (6) async Session API
# ---------------------------------------------------------------------------

def test_execute_async_returns_future_of_result():
    s, health, inj, t = _resilient_session()
    fut = s.execute_async(_portable_query())
    r = fut.result(timeout=60)
    assert r.mode == "training" and r.status == "ok"
    assert r.failovers == 0 and not r.degraded


def test_map_preserves_input_order():
    s, health, inj, t = _resilient_session()
    qs = [_portable_query(),
          relational.select("T", column="v", lo=0.0)]
    out = s.map(qs, workers=2)
    assert [r.sig for r in out] == \
        [signature(q, s.bigdawg.catalog) for q in qs]


def test_execute_async_parse_error_is_eager():
    s, health, inj, t = _resilient_session()
    with pytest.raises(QueryParseError):        # at the call site, not in
        s.execute_async("RELATIONAL(select from)")   # the future
    with pytest.raises(QueryParseError):
        s.map(["RELATIONAL(select * from T)", "RELATIONAL(oops"])


# ---------------------------------------------------------------------------
# (7) qlang SQL surface
# ---------------------------------------------------------------------------

def test_sql_select_matches_programmatic_signature():
    q_sql = parse_text("RELATIONAL(select * from A where v >= 0.5 and v <= 2)")
    q_api = relational.select("A", column="v", lo=0.5, hi=2)
    assert signature(q_sql, None) == signature(q_api, None)


def test_sql_where_folds_bounds_per_column():
    q = parse_text("RELATIONAL(select * from A "
                   "where v >= 0.5 and v < 2.5 and v >= 1.0)")
    assert q.op == "select"
    assert q.attrs["lo"] == 1.0 and q.attrs["hi"] == 2.5   # tightest bounds
    qe = parse_text("RELATIONAL(select * from A where v = 3)")
    assert qe.attrs["lo"] == 3 and qe.attrs["hi"] == 3     # equality pins


def test_sql_column_list_projects():
    q = parse_text("RELATIONAL(select a, b from A where v > 0)")
    assert q.op == "project" and q.attrs["columns"] == ["a", "b"]
    assert q.inputs[0].op == "select" and q.inputs[0].attrs["column"] == "v"
    bare = parse_text("RELATIONAL(select * from A)")
    assert bare.op == "scope"                   # plain table reference


def test_sql_errors_and_island_guard():
    for text in ("RELATIONAL(select from A)",       # no columns
                 "RELATIONAL(select * A)",          # missing FROM
                 "RELATIONAL(select * from)",       # missing table
                 "RELATIONAL(select * from A where v > x)",  # non-numeric
                 "ARRAY(select * from A)"):         # relational-only syntax
        with pytest.raises(QueryParseError):
            parse_text(text)


def test_sql_pipeline_placeholder():
    q = parse_text("RELATIONAL(join(A, B, left_on=k, right_on=k)) "
                   "|> RELATIONAL(select * from _ where v > 0)")
    assert q.op == "select" and q.inputs[0].op == "join"


# ---------------------------------------------------------------------------
# (8) adaptive shedding (AIMD bound, degrade-before-shed)
# ---------------------------------------------------------------------------

class _FakeReport:
    def __init__(self, mode="production"):
        self.mode = mode
        self.cache_hit = mode == "production"
        self.replanned = False
        self.explored = False
        self.degraded = False
        self.failovers = 0
        self.status = "ok"


class _FakeBD:
    """Stand-in middleware: instant (or slow) serves, records degrade flags."""

    def __init__(self, mode="production", delay=0.0, health=None):
        self.mode = mode
        self.delay = delay
        self.health = health
        self.degrade_calls = []

    def execute(self, query, mode="auto", degrade=False):
        self.degrade_calls.append(degrade)
        if self.delay:
            time.sleep(self.delay)
        return _FakeReport(self.mode)


def test_adaptive_bound_grows_under_target():
    srv = QueryServer(_FakeBD(), latency_target_s=10.0)
    b0 = srv._bound
    for _ in range(5):
        srv.submit("q")
    assert srv._bound == b0 + 5
    assert srv.stats["latency_ewma"] > 0.0
    assert srv.stats["shed"] == 0


def test_adaptive_bound_halves_over_target_with_floor():
    srv = QueryServer(_FakeBD(delay=0.002), latency_target_s=1e-6)
    for _ in range(12):
        srv.submit("q")
    assert srv._bound == 1.0                    # halved down to the floor


def test_adaptive_bound_capped_at_max_pending():
    srv = QueryServer(_FakeBD(), max_pending=9, latency_target_s=10.0)
    assert srv._bound == 9.0
    for _ in range(5):
        srv.submit("q")
    assert srv._bound == 9.0


def test_training_requests_excluded_from_latency_ewma():
    srv = QueryServer(_FakeBD(mode="training"), latency_target_s=10.0)
    b0 = srv._bound
    srv.submit("q")
    assert srv.stats["latency_ewma"] == 0.0 and srv._bound == b0


def test_degrade_before_shed_admission_ladder():
    bd = _FakeBD(health=object())               # middleware CAN degrade
    srv = QueryServer(bd, latency_target_s=10.0)
    bound = int(srv._bound)
    srv._pending = bound                        # at the bound: degrade rung
    assert srv._try_admit() == "degrade"
    srv._pending = 2 * bound                    # past twice the bound: shed
    assert srv._try_admit() is None
    assert srv.stats["shed"] == 1
    # without a health registry there is no degraded planning: shed directly
    srv2 = QueryServer(_FakeBD(health=None), latency_target_s=10.0)
    srv2._pending = int(srv2._bound)
    assert srv2._try_admit() is None


def test_degraded_admission_reaches_middleware():
    bd = _FakeBD(health=object())
    srv = QueryServer(bd, latency_target_s=10.0)
    pend0 = srv._pending = int(srv._bound)      # force the degrade rung
    out = srv.submit_many(["q"], workers=1)
    assert len(out) == 1 and not isinstance(out[0], Overloaded)
    assert bd.degrade_calls == [True]
    assert srv.stats["degraded"] == 0           # fake report isn't degraded
    assert srv._pending == pend0                # slot released


def test_overloaded_reason_names_the_policy():
    srv = QueryServer(_FakeBD(), latency_target_s=10.0)
    srv._pending = 2 * int(srv._bound)
    out = srv.submit_many(["q"], workers=1)
    assert isinstance(out[0], Shed) and out[0].reason == "latency_target"
    legacy = QueryServer(_FakeBD(), max_pending=1)
    legacy._pending = 1
    out = legacy.submit_many(["q"], workers=1)
    assert isinstance(out[0], Overloaded) and out[0].reason == "max_pending"


# ---------------------------------------------------------------------------
# (9) BatchServer on the shared request pool
# ---------------------------------------------------------------------------

def _toy_batch_server(slots=3, max_len=16, V=8):
    def init_cache(b, ml):
        return {"k": jnp.zeros((b, ml, 2), jnp.float32)}

    def prefill(params, tok):
        first = int(np.asarray(tok).sum()) % V
        logits = jnp.zeros((1, V), jnp.float32).at[0, first].set(1.0)
        rows = {"k": jnp.ones((1, tok.shape[1], 2), jnp.float32)}
        return logits, rows, tok.shape[1]

    def decode(params, cache, tokens, pos):
        return (tokens + 1) % V, cache

    return BatchServer(slots=slots, max_len=max_len, prefill_fn=prefill,
                       decode_fn=decode, params=None,
                       init_cache_fn=init_cache)


def test_batchserver_serve_matches_run():
    rng = np.random.default_rng(3)
    def reqs():
        return [Request(rid=i,
                        prompt=rng.integers(1, 5, 3 + i % 4).astype(np.int32),
                        max_new_tokens=5) for i in range(7)]
    rng = np.random.default_rng(3)
    seq = _toy_batch_server().run(reqs())
    rng = np.random.default_rng(3)
    par = _toy_batch_server().serve(reqs(), workers=3)
    assert all(r.done for r in par)
    assert [r.out_tokens for r in par] == [r.out_tokens for r in seq]
