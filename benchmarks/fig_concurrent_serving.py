"""Concurrent admission: requests/sec through ``QueryServer`` at 1/2/4
client threads on mixed cold+warm signature traffic (ISSUE 4 tentpole).

The serving stack admits requests from many threads at once: per-signature
locking in the middleware (one training per cold signature, different
signatures in parallel), a thread-safe monitor (batched record flushing)
and cost model, and budgeted alternate exploration scheduled as background
host-pool tasks — ZERO exploration time on the request path (the serve only
schedules; ``explore_seconds_off_path`` in the JSON is accounted entirely
by background workers).

Workload: ``S`` distinct signatures of two families —

  * join-heavy: ``select(join(jl_i, jr_i))`` over host-side numpy tables,
    columnar-pinned end to end (host sort-merge joins release the GIL — the
    work class where request threads genuinely overlap on a multi-core
    host), and
  * analytic: ``tfidf(haar(select(waves)))`` with real cross-engine plan
    diversity, so training produces k-best alternates and the background
    exploration path has something to try.

Entries (all measured with exploration ENABLED, ``explore_budget=0.02``,
budget clock re-anchored per round so no round inherits banked credit):

  * ``warm_threadsK``        — all signatures pre-trained, R requests
                               round-robin from K client threads
                               (``rps_speedup_vs_1`` is the headline:
                               expect >=1.3x at K=4 on a 2-core runner),
  * ``mixed_cold_warm_threads4`` — half the signatures cold, 4 threads:
                               the admission-under-stampede shape
                               (``trainings`` must equal the cold count).

Run: PYTHONPATH=src python benchmarks/fig_concurrent_serving.py [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (BigDAWG, ColumnarTable, DenseTensor, array,
                        relational)
from repro.core.executor import DEFAULT_HOST_WORKERS
from repro.runtime import QueryServer

N_JOIN = 3          # join-heavy signatures (host overlap carriers)
N_ANALYTIC = 1      # analytic signatures (plan diversity -> exploration)
N_SIGS = N_JOIN + N_ANALYTIC


def make_bigdawg(join_rows: int, waves_shape=(48, 128)) -> BigDAWG:
    """A middleware with join tables registered as host-side (numpy)
    columnar containers — per-request join work is pure GIL-releasing host
    numpy — plus one dense table for the analytic family."""
    bd = BigDAWG(train_plans=4, train_repeats=1, explore_budget=0.02)
    bd.replan_factor = float("inf")      # measure admission, not replanning
    for i in range(N_JOIN):
        for side_idx, side in enumerate(("jl", "jr")):
            r = np.random.default_rng(1000 + 2 * i + side_idx)
            keys = r.permutation(join_rows).astype(np.int32)
            bd.register(f"{side}{i}", ColumnarTable(
                {"i": keys,
                 "value": r.normal(size=join_rows).astype(np.float32)}),
                engine="columnar")
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=waves_shape).astype(np.float32))),
        engine="dense_array")
    return bd


def query(i: int):
    if i < N_JOIN:      # columnar-pinned: join + a selective filter on top
        return relational.select(
            relational.join(f"jl{i}", f"jr{i}", left_on="i", right_on="i"),
            column="l_value", lo=0.0)
    return array.tfidf(array.haar(        # cross-engine candidates
        relational.select("waves", column="value", lo=0.0), levels=2))


def traffic(requests: int):
    """7 join requests : 1 analytic — joins carry the host overlap, the
    analytic keeps the cross-engine exploration path exercised."""
    return [query(N_JOIN + (i // 8) % N_ANALYTIC) if i % 8 == 7
            else query(i % N_JOIN) for i in range(requests)]


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    join_rows = 60_000 if fast else 400_000
    requests = 8 if fast else 24

    report = {}

    # -- warm phase: every signature pre-trained, exploration enabled -------
    bd = make_bigdawg(join_rows)
    srv = QueryServer(bd)
    # this figure measures WARM concurrent throughput, so usage-drift
    # retraining must not fire mid-round: the drift signal compares each
    # plan's last usage snapshot against now, and peak RSS (ru_maxrss, which
    # the snapshot tracks) is monotone — on a small host it more than
    # doubles as the join tables first stream through, so plans trained
    # early would legitimately drift-retrain inside a measured round and
    # poison the all-production rps.  Drift retraining has its own coverage
    # (tests + the adaptive-replan figure); pin it off here.
    bd.monitor.DRIFT_THRESHOLD = float("inf")
    srv.warm([query(i) for i in range(N_SIGS)])
    srv.submit_many(traffic(N_SIGS), workers=2)            # jit/pool warmup
    bd.drain_explorations()

    base_rps = None
    rounds = 1 if fast else 2            # full mode: best-of-2 damps OS noise
    for threads in (1, 2, 4):
        best = None
        for _ in range(rounds):
            bd.drain_explorations()      # previous round's background work
            # re-anchor the budget clock: cumulative accounting would let
            # early rounds bank unspent exploration credit that the last
            # round burns in a burst, skewing the thread-count comparison
            bd.reset_exploration_budget()
            served0, expl0 = bd.serve_seconds, bd.explore_seconds
            out = srv.serve(traffic(requests), workers=threads)
            bd.drain_explorations()      # this round's trials land
            # per-round accounting, so the selected round's seconds fields
            # all describe the same requests
            out["serve_delta"] = bd.serve_seconds - served0
            out["explore_delta"] = bd.explore_seconds - expl0
            if best is None or out["rps"] > best["rps"]:
                best = out
        out = best
        reps = out["reports"]
        assert all(r.mode == "production" for r in reps)
        rps = out["rps"]
        if base_rps is None:
            base_rps = rps
        report[f"warm_threads{threads}"] = {
            "threads": threads,
            "rounds": rounds,
            "requests": len(reps),
            "seconds": round(out["seconds"], 6),
            "rps": round(rps, 3),
            "rps_speedup_vs_1": round(rps / base_rps, 3),
            "trainings": 0,
            "explorations": sum(1 for r in reps if r.explored),
            # serve-path seconds vs background exploration seconds FOR THE
            # REPORTED ROUND: the request path schedules trials but never
            # executes them
            "serve_seconds_on_path": round(out["serve_delta"], 6),
            "explore_seconds_off_path": round(out["explore_delta"], 6),
            "workers_host": DEFAULT_HOST_WORKERS,
        }
        e = report[f"warm_threads{threads}"]
        print(f"# warm threads={threads} requests={e['requests']} "
              f"rps={e['rps']:.2f} speedup={e['rps_speedup_vs_1']:.2f}x "
              f"explore_off_path={e['explore_seconds_off_path']:.3f}s",
              file=sys.stderr, flush=True)

    # -- mixed cold+warm stampede at 4 threads -------------------------------
    bd2 = make_bigdawg(join_rows)
    srv2 = QueryServer(bd2)
    srv2.warm([query(i) for i in range(N_SIGS // 2)])      # half warm
    t0 = time.perf_counter()
    reps = srv2.submit_many(traffic(requests), workers=4)
    wall = time.perf_counter() - t0
    bd2.drain_explorations()
    trainings = sum(1 for r in reps if r.mode == "training")
    assert trainings == N_SIGS - N_SIGS // 2, \
        f"per-signature locking broke: {trainings} trainings"
    report["mixed_cold_warm_threads4"] = {
        "threads": 4,
        "requests": len(reps),
        "seconds": round(wall, 6),
        "rps": round(len(reps) / max(wall, 1e-9), 3),
        "rps_speedup_vs_1": 0.0,     # no 1-thread baseline for this phase
        "trainings": trainings,
        "explorations": sum(1 for r in reps if r.explored),
        "serve_seconds_on_path": round(bd2.serve_seconds, 6),
        "explore_seconds_off_path": round(bd2.explore_seconds, 6),
        "workers_host": DEFAULT_HOST_WORKERS,
    }
    e = report["mixed_cold_warm_threads4"]
    print(f"# mixed threads=4 requests={e['requests']} rps={e['rps']:.2f} "
          f"trainings={e['trainings']}", file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
