"""Shared benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import gc
import time

import jax
import numpy as np


def timed_loop(fn, n: int, on_error=None):
    """Time ``n`` sequential calls of ``fn()`` with the collector paused.

    Returns ``(lats_ms, results, failed)``: per-call wall milliseconds as
    an ndarray, the collected return values, and how many calls raised.
    Collector pauses (host-allocation-heavy serves) would put 30+ ms GC
    spikes into any phase's p99 — collect up front, then keep the collector
    out of the timed loop.  A raised exception propagates unless
    ``on_error`` is given, in which case it is called with the exception
    and the call counts as failed."""
    lats, results, failed = [], [], 0
    gc.collect()
    gc.disable()
    try:
        for _ in range(n):
            t0 = time.perf_counter()
            try:
                results.append(fn())
            except Exception as exc:        # noqa: BLE001 — counted
                if on_error is None:
                    raise
                failed += 1
                on_error(exc)
            lats.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    return np.asarray(lats) * 1e3, results, failed


def bench(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall seconds of fn(*args), blocking on device results."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(getattr(r, "__dict__", r)) or [0])
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(getattr(r, "__dict__", r)) or [0])
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], r


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
