"""Shared benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import time

import jax


def bench(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall seconds of fn(*args), blocking on device results."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(getattr(r, "__dict__", r)) or [0])
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(getattr(r, "__dict__", r)) or [0])
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], r


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
