"""Plan-level kernel fusion: measure the warm-serve win from compiling a
cached plan's same-engine chains into single jitted callables (ISSUE 8
tentpole; core/fuseplan.py).

The unfused executor pays one host round trip per node — argument gather,
engine shim call, container wrap, async-dispatch bookkeeping — even when a
whole chain is pure device math.  Fusion collapses each maximal dense-array
chain into ONE jitted call, so a warm serve of an N-node plan makes
``N - n_fused_nodes + n_segments`` dispatches instead of N.

Two entries per width, both on the fig_host_parallel pipeline family:

  pipeline_widthW       — the fig_host_parallel DAG verbatim (W branches of
      select->haar->bin_hist->tfidf, dense add-reduction).  ``select`` is
      columnar-homed and ``bin_hist`` is not fusable, so each branch's haar
      stands alone (1-node chains stay unfused) and fusion captures the
      tfidf+add reduction tree (2W-1 of the ~4W nodes): the realistic
      partially-fusable case.
  pipeline_dense_widthW — the same pipeline with the bin_hist stage dropped
      and every array op planned dense: each branch's haar->tfidf chain plus
      the whole add tree fuse into ONE segment (3W-1 nodes).  The
      best-case bound for the dispatch-overhead claim.

Per entry this emits JSON (serve times are medians over ``iters`` warm
serves — training/compile excluded; both paths run the SAME plan under the
level-concurrent executor, so the delta is purely fusion):

  * ``unfused_s`` / ``fused_s``       — median warm serve seconds,
  * ``rps_unfused`` / ``rps_fused``   — 1/median: warm serves per second,
  * ``rps_speedup``                   — rps_fused / rps_unfused,
  * ``dispatch_per_node_unfused_s`` / ``dispatch_per_node_fused_s``
        — median serve seconds divided by node count: the per-node
          dispatch overhead fusion is supposed to lower,
  * ``n_segments`` / ``n_fused_nodes`` / ``fusion_fallbacks``.

In full mode (not ``--fast``), when an XLA backend is live and no segment
fell back, the pipeline_dense entries must clear >= 1.15x rps — the
tentpole's acceptance bar.  Fast mode records honest numbers but asserts
only equivalence-adjacent invariants (segments formed, zero fallbacks).

Run: PYTHONPATH=src python benchmarks/fig_fusion.py [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (BigDAWG, DenseTensor, array, execute_plan, fuse_plan,
                        relational)
from repro.core.planner import Plan
try:                                     # repo root on sys.path (run.py)
    from benchmarks.fig_host_parallel import pipeline_dag
except ImportError:                      # invoked as a script from CI
    from fig_host_parallel import pipeline_dag

# only select is columnar-homed (relational island); every array op —
# including the unfusable bin_hist seam — lands on dense_array, so segment
# boundaries are dispatch seams, not cast seams (a columnar bin_hist would
# serialize W casts inside the fused segment's single host task)
_COLUMNAR_OPS = {"select"}

SPEEDUP_BAR = 1.15


def pipeline_dense_dag(width: int):
    """The pipeline family's all-fusable variant: select feeds haar->tfidf
    directly (no bin_hist seam), reduced by the dense add tree."""
    def branch():
        s = relational.select("waves", column="value", lo=0.0)
        return array.tfidf(array.haar(s, levels=2))
    outs = [branch() for _ in range(width)]
    while len(outs) > 1:
        outs = [array.add(a, b) if b is not None else a
                for a, b in zip(outs[0::2],
                                outs[1::2] + [None] * (len(outs) % 2))]
    return outs[0]


def fusion_plan(query) -> Plan:
    """Columnar where the data model demands it, dense_array everywhere
    else — the maximal-fusion assignment for the pipeline family."""
    return Plan(tuple(
        (i, "columnar" if n.op in _COLUMNAR_OPS else "dense_array")
        for i, n in enumerate(query.nodes())))


def _median_serve(query, plan, catalog, iters, fused=None):
    execute_plan(query, plan, catalog, concurrent=True, fused=fused)  # warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        execute_plan(query, plan, catalog, concurrent=True, fused=fused)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    iters = 3 if fast else 15
    n, t = (16, 64) if fast else (96, 256)
    widths = (2, 4) if fast else (4, 8)

    rng = np.random.default_rng(0)
    bd = BigDAWG()
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")

    backend = jax.default_backend()
    report = {}
    for family, build in (("pipeline", pipeline_dag),
                          ("pipeline_dense", pipeline_dense_dag)):
        for width in widths:
            q = build(width)
            plan = fusion_plan(q)
            fused = fuse_plan(q, plan, bd.catalog, cost_model=bd.cost_model)
            unfused_s = _median_serve(q, plan, bd.catalog, iters)
            fused_s = _median_serve(q, plan, bd.catalog, iters, fused=fused)
            res = execute_plan(q, plan, bd.catalog, concurrent=True,
                               fused=fused)
            n_nodes = len(q.nodes())
            speedup = unfused_s / max(fused_s, 1e-9)
            entry = {
                "n_nodes": n_nodes,
                "width": width,
                "backend": backend,
                "n_segments": len(fused.segments),
                "n_fused_nodes": fused.n_fused_nodes,
                "fusion_fallbacks": res.fusion_fallbacks,
                "unfused_s": round(unfused_s, 6),
                "fused_s": round(fused_s, 6),
                "rps_unfused": round(1.0 / max(unfused_s, 1e-9), 2),
                "rps_fused": round(1.0 / max(fused_s, 1e-9), 2),
                "rps_speedup": round(speedup, 3),
                "dispatch_per_node_unfused_s": round(unfused_s / n_nodes, 8),
                "dispatch_per_node_fused_s": round(fused_s / n_nodes, 8),
            }
            report[f"{family}_width{width}"] = entry
            print(f"# {family} width={width} nodes={n_nodes} "
                  f"segments={len(fused.segments)} "
                  f"fused_nodes={fused.n_fused_nodes} "
                  f"unfused={unfused_s:.5f}s fused={fused_s:.5f}s "
                  f"speedup={speedup:.2f}x", file=sys.stderr, flush=True)

            # equivalence-adjacent invariants hold in every mode: segments
            # really formed, nothing fell back, results fused == unfused
            assert fused.segments and res.fusion_fallbacks == 0
            base = execute_plan(q, plan, bd.catalog, concurrent=True)
            np.testing.assert_allclose(
                np.asarray(res.value.data, np.float32),
                np.asarray(base.value.data, np.float32),
                rtol=1e-5, atol=1e-5)

    if not fast and backend is not None:
        # the acceptance bar: on a live XLA backend the all-fusable family
        # must clear >= 1.15x warm rps with strictly lower per-node overhead
        for width in widths:
            e = report[f"pipeline_dense_width{width}"]
            if e["fusion_fallbacks"] == 0:
                assert e["rps_speedup"] >= SPEEDUP_BAR, \
                    f"pipeline_dense_width{width}: {e['rps_speedup']}x " \
                    f"< {SPEEDUP_BAR}x"
                assert (e["dispatch_per_node_fused_s"]
                        < e["dispatch_per_node_unfused_s"])

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
