"""Paper Fig. 4: middleware overhead — BigDAWG execute() vs direct engine
invocation.

As in the paper, these are single-engine queries issued through the
*degenerate island* (full engine power, no location transparency), so the
difference is pure middleware cost on the production path: signature
computation, monitor lookup + recording, a signature-keyed plan-cache hit
(no plan enumeration or key parsing), concurrent topological-level dispatch,
the predicted/measured divergence check of the online re-planner, and result
delivery in the island's data model.

Claim reproduced: overhead is a small percentage for long queries and only a
large share for very short ones ("There is a minimum overhead incurred which
may be a larger percentage for queries of shorter duration").
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core import BigDAWG, DenseTensor, ENGINES, degenerate
from benchmarks.common import bench, row

scidb = degenerate("dense_array")


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    print("# fig4: name,us_per_call,derived", flush=True)
    bd = BigDAWG()
    rng = np.random.default_rng(0)
    for n in ((64, 128) if fast else (64, 256, 1024, 2048)):
        name = f"W{n}"
        w = DenseTensor(jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)))
        bd.register(name, w, engine="dense_array")
        q = scidb.matmul(scidb.matmul(name, name), name)

        bd.execute(q, mode="training")       # warm + record
        t_mw, _ = bench(lambda: bd.execute(q, mode="production"), iters=5)

        eng = ENGINES["dense_array"]
        def direct():
            return eng.run("matmul", {}, eng.run("matmul", {}, w, w), w)
        t_direct, _ = bench(direct, iters=5)

        ovh = (t_mw - t_direct) / t_direct * 100.0
        row(f"fig4.direct.n{n}", t_direct * 1e6)
        row(f"fig4.bigdawg.n{n}", t_mw * 1e6, f"overhead={ovh:.1f}%")


if __name__ == "__main__":
    main()
