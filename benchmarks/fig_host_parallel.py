"""Host-parallel executor: measure the win from thread-pooling a topological
level's host work (ISSUE 3 tentpole).

The pre-PR-3 concurrent mode only overlapped JAX *async dispatch*: the
numpy-eager engine work — columnar sort-merge joins, COO conversions, every
cast hop — still serialized on the host, so a level of W independent
host-heavy branches ran no faster than sequential.  The rebuilt executor
submits every node of a level (including its multi-hop input casts) to a
shared host thread pool; numpy releases the GIL on real arrays, so that work
genuinely overlaps.

Two DAG families, both fig_planner_scaling-style wide trees:

  pipeline_widthW — W independent select->haar->bin_hist->tfidf branches on
      the columnar engine (one dense->columnar cast per branch), reduced by
      a dense add tree.  Mostly XLA-backed ops: the threaded win here is
      bounded by how much the XLA CPU runtime already parallelizes.
  join_widthW — W independent columnar sort-merge joins (np.argsort /
      searchsorted dominate: single-threaded numpy that releases the GIL),
      reduced the same way.  This is the workload the ROADMAP names
      ("thread-pool the numpy-eager engine ops (columnar join, ...)"), and
      where host overlap pays even on small machines.

Per entry this emits JSON:

  * ``sequential_s``          — post-order, block-per-node (training mode),
  * ``inline_concurrent_s``   — level dispatch, single-threaded
                                (``host_workers=1``: the pre-PR-3 behavior),
  * ``threaded_s``            — level dispatch over the shared host pool,
  * ``host_speedup``          — inline_concurrent_s / threaded_s: the pure
                                host-overlap win (same plan, same levels),
  * ``speedup_vs_sequential`` — sequential_s / threaded_s.

Speedups scale with cores (``workers`` is recorded): on a 2-core CI runner
expect ~1.1-1.3x on the join family and ~1x on the XLA-bound pipeline
family; on an n-core host the ceiling is min(W, n).

Run: PYTHONPATH=src python benchmarks/fig_host_parallel.py [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (BigDAWG, ColumnarTable, DenseTensor, array,
                        execute_plan, relational, topo_levels)
from repro.core.executor import DEFAULT_HOST_WORKERS
from repro.core.planner import Plan

# branch stages that carry the host-side columnar work
_COLUMNAR_OPS = {"select", "haar", "bin_hist", "tfidf", "join", "count"}


def _add_tree(outs):
    """Balanced dense add-reduction of a list of branch outputs."""
    while len(outs) > 1:
        outs = [array.add(a, b) if b is not None else a
                for a, b in zip(outs[0::2],
                                outs[1::2] + [None] * (len(outs) % 2))]
    return outs[0]


def pipeline_dag(width: int):
    """W independent columnar pipelines — the fig_planner_scaling shape."""
    def branch():
        s = relational.select("waves", column="value", lo=0.0)
        h = array.haar(s, levels=2)
        return array.tfidf(array.bin_hist(h, nbins=8, levels=2))
    return _add_tree([branch() for _ in range(width)])


def join_dag(width: int):
    """W independent sort-merge joins (host numpy), counted to scalars and
    add-reduced."""
    return _add_tree([
        array.count(relational.join(f"jl{i}", f"jr{i}",
                                    left_on="i", right_on="i"))
        for i in range(width)])


def host_heavy_plan(query) -> Plan:
    """Columnar stages on the columnar engine, reduction tree on dense."""
    return Plan(tuple(
        (i, "columnar" if n.op in _COLUMNAR_OPS else "dense_array")
        for i, n in enumerate(query.nodes())))


def measure(query, plan, catalog, iters, **kw):
    execute_plan(query, plan, catalog, **kw)          # warm (jit, pool spin-up)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        execute_plan(query, plan, catalog, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    iters = 1 if fast else 3
    n, t = (16, 64) if fast else (96, 256)
    # fast join inputs stay above the executor's HOST_TASK_MIN_BYTES auto-
    # threading gate, so the CI smoke exercises the pool, not the fallback
    join_rows = 150_000 if fast else 800_000
    widths = (2, 4) if fast else (4, 8)

    rng = np.random.default_rng(0)
    bd = BigDAWG()
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    for i in range(max(widths)):
        for side_idx, side in enumerate(("jl", "jr")):
            # deterministic seeds (hash() is salted per process)
            r = np.random.default_rng(1000 + 2 * i + side_idx)
            keys = r.permutation(join_rows).astype(np.int32)
            bd.register(f"{side}{i}", ColumnarTable(
                {"i": jnp.asarray(keys),
                 "value": jnp.asarray(
                     r.normal(size=join_rows).astype(np.float32))}),
                engine="columnar")

    report = {}
    for family, build in (("pipeline", pipeline_dag), ("join", join_dag)):
        for width in widths:
            q = build(width)
            plan = host_heavy_plan(q)
            seq = measure(q, plan, bd.catalog, iters)
            inline = measure(q, plan, bd.catalog, iters, concurrent=True,
                             host_workers=1)
            threaded = measure(q, plan, bd.catalog, iters, concurrent=True)
            res = execute_plan(q, plan, bd.catalog, concurrent=True)
            report[f"{family}_width{width}"] = {
                "n_nodes": len(q.nodes()),
                "width": width,
                "levels": len(topo_levels(q)),
                "n_casts": res.n_casts,
                "workers": DEFAULT_HOST_WORKERS,
                "sequential_s": round(seq, 6),
                "inline_concurrent_s": round(inline, 6),
                "threaded_s": round(threaded, 6),
                "host_speedup": round(inline / max(threaded, 1e-9), 3),
                "speedup_vs_sequential": round(seq / max(threaded, 1e-9), 3),
            }
            print(f"# {family} width={width} nodes={len(q.nodes())} "
                  f"seq={seq:.4f}s inline={inline:.4f}s "
                  f"threaded={threaded:.4f}s "
                  f"host_speedup={inline / max(threaded, 1e-9):.2f}x",
                  file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
