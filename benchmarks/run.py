"""Benchmark runner — one section per paper table/figure.

  fig1     count/distinct engine crossover          (paper Fig. 1)
  matmul   dense vs join-aggregate matrix multiply  (paper §II anecdote)
  fig4     middleware overhead                      (paper Fig. 4)
  fig5     hybrid medical analytic                  (paper Fig. 5, §IV-B)
  planner  truncated-product vs container-DP planner scaling
  roofline dry-run roofline table (requires sweep artifacts)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig1_engine_crossover, fig4_overhead,
                            fig5_polystore_analytic, fig_planner_scaling,
                            matmul_engines, roofline)
    sections = [
        ("fig1", fig1_engine_crossover.main),
        ("matmul", matmul_engines.main),
        ("fig4", fig4_overhead.main),
        ("fig5", fig5_polystore_analytic.main),
        ("planner", fig_planner_scaling.main),
        ("roofline", roofline.main),
    ]
    failed = []
    for name, fn in sections:
        print(f"\n==== {name} ====", flush=True)
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark sections completed", flush=True)


if __name__ == '__main__':
    main()
