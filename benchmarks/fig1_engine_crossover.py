"""Paper Fig. 1: count vs distinct across engines and sizes.

Claim reproduced: the array engine wins `count` (O(1) container metadata, the
SciDB side of Fig. 1) while the columnar engine wins `distinct` when the
array layout carries padding (the PostGRES side) — no single engine wins both.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import DenseTensor, ENGINES
from repro.core import cast as castmod
from benchmarks.common import bench, row


def make_padded_dense(n_valid: int, pad_factor: int = 4, seed: int = 0):
    """Sparse-ish data in a padded dense array (fill = 0), plus its compacted
    columnar form — the same logical table in two engines."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, max(n_valid // 8, 2),
                        size=n_valid).astype(np.float32)
    dense = np.zeros(n_valid * pad_factor, np.float32)
    idx = rng.choice(dense.size, n_valid, replace=False)
    dense[idx] = vals
    d = DenseTensor(jnp.asarray(dense), valid_count=n_valid)
    col = castmod.cast(DenseTensor(jnp.asarray(vals)), "columnar")
    return d, col


def main():
    print("# fig1: name,us_per_call,derived", flush=True)
    for n in (10_000, 100_000, 1_000_000):
        d, col = make_padded_dense(n)
        t, _ = bench(ENGINES["dense_array"].run, "count", {}, d)
        row(f"fig1.count.dense_array.n{n}", t * 1e6)
        t, _ = bench(ENGINES["columnar"].run, "count", {}, col)
        row(f"fig1.count.columnar.n{n}", t * 1e6)
        t, _ = bench(ENGINES["dense_array"].run, "distinct", {}, d)
        row(f"fig1.distinct.dense_array.n{n}", t * 1e6)
        t, _ = bench(ENGINES["columnar"].run, "distinct", {}, col)
        row(f"fig1.distinct.columnar.n{n}", t * 1e6)
    # crossover assertion at the largest size
    d, col = make_padded_dense(1_000_000)
    tc_d, _ = bench(ENGINES["dense_array"].run, "count", {}, d)
    tc_c, _ = bench(ENGINES["columnar"].run, "count", {}, col)
    td_d, _ = bench(ENGINES["dense_array"].run, "distinct", {}, d)
    td_c, _ = bench(ENGINES["columnar"].run, "distinct", {}, col)
    row("fig1.crossover", 0.0,
        f"count: dense {tc_c/tc_d:.1f}x faster; "
        f"distinct: columnar {td_d/td_c:.1f}x faster")


if __name__ == "__main__":
    main()
