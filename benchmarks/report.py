"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json

from benchmarks.roofline import load, FIX_HINTS

ORDER = ["internlm2-1.8b", "codeqwen1.5-7b", "qwen2-72b", "glm4-9b",
         "mamba2-370m", "internvl2-26b", "zamba2-7b", "seamless-m4t-medium",
         "deepseek-v2-lite-16b", "grok-1-314b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _by_cell(rows):
    return {(r["arch"], r["shape"]): r for r in rows}


def dryrun_table():
    pod = _by_cell(load("pod_16x16"))
    mp = _by_cell(load("multipod_2x16x16"))
    print("| arch | shape | pod 16x16: HBM/dev | fits 16G | compile s | "
          "multipod 2x16x16: HBM/dev | fits | compile s |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ORDER:
        for s in SHAPES:
            r, r2 = pod.get((a, s)), mp.get((a, s))
            if r is None:
                continue
            if not r.get("applicable", True):
                print(f"| {a} | {s} | SKIP (long-context needs sub-quadratic "
                      f"attention; full-attention family) | | | | | |")
                continue
            m, m2 = r.get("memory", {}), (r2 or {}).get("memory", {})
            print(f"| {a} | {s} | {r['hbm_bytes_per_device']/1e9:.2f} GB "
                  f"| {'Y' if r['fits_16g'] else 'N'} "
                  f"| {m.get('compile_s', 0):.1f} "
                  f"| {(r2 or {}).get('hbm_bytes_per_device', 0)/1e9:.2f} GB "
                  f"| {'Y' if (r2 or {}).get('fits_16g') else '-'} "
                  f"| {m2.get('compile_s', 0):.1f} |")


def roofline_table():
    pod = _by_cell(load("pod_16x16"))
    print("| arch | shape | t_compute s | t_memory s | t_collective s | "
          "dominant | MODEL/HLO flops | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ORDER:
        for s in SHAPES:
            r = pod.get((a, s))
            if r is None or not r.get("applicable", True):
                continue
            rf = r.get("roofline")
            if rf is None:
                continue
            print(f"| {a} | {s} | {rf['t_compute']:.3f} | {rf['t_memory']:.3f} "
                  f"| {rf['t_collective']:.3f} | {rf['dominant']} "
                  f"| {rf['useful_flops_ratio']:.3f} "
                  f"| {rf['roofline_fraction']:.4f} "
                  f"| {FIX_HINTS[rf['dominant']][:70]} |")


def main():
    print("### Dry-run (memory compiles)\n")
    dryrun_table()
    print("\n### Roofline (single-pod, cost probes)\n")
    roofline_table()


if __name__ == "__main__":
    main()
