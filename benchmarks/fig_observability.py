"""End-to-end observability: what request tracing costs, and what it shows
(ISSUE 10 tentpole figure).

One middleware stack serves the canonical warm cross-island query
(``RELATIONAL(join) |> ARRAY(matmul)``) with the tracer flag flipped
between measurement segments, the segment order rotated every round so
drift and order effects cancel:

  * ``tracer_off`` / ``tracer_off_b`` — the disabled-path null test: the
    fully instrumented middleware with the tracer off, measured twice per
    round.  Every instrumentation site guards on ``span is not None`` and
    makes no clock reads or allocations when disabled, so the two arms
    are *identical* — any spread between their median-latency rps is
    measurement noise, and that spread (``off_noise_pct``) bounds what
    the disabled tracer could possibly cost.  Asserted < 2% in full mode;
    the checked-in BENCH_observability.json records the bound.
  * ``tracer_on`` — tracer on: every warm serve builds a full span tree
    (request / plan / cache_hit / ivm_patch / engine_op / cast).
    ``tracing_overhead_pct`` prices the *enabled* tracer against the
    faster off arm.

The report also carries one ``sample_trace`` — a warm serve's span
records, exactly ``Result.trace.to_dict()`` — and the traced stack's
``metrics`` snapshot (bd.* counters plus the ``bd.serve_latency``
histogram p50/p95/p99), so the figure documents the observable surface,
not just its price.

Run: PYTHONPATH=src python benchmarks/fig_observability.py [--fast]
"""
from __future__ import annotations

import json
import sys

import numpy as np
import jax.numpy as jnp

from common import timed_loop
from repro.core import ColumnarTable, DenseTensor, connect

TEXT_Q = ("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
          "|> ARRAY(matmul(_, W))")


def make_session(trace: bool):
    rng = np.random.default_rng(0)
    M = rng.normal(size=(32, 24)).astype(np.float32)
    perm = rng.permutation(24)
    W = rng.normal(size=(24, 8)).astype(np.float32)
    ii, kk = np.meshgrid(np.arange(32), np.arange(24), indexing="ij")
    A = ColumnarTable({"i": ii.ravel().astype(np.int32),
                       "key": kk.ravel().astype(np.int32),
                       "value": M.ravel()})
    B = ColumnarTable({"key": np.arange(24, dtype=np.int32),
                       "j": perm.astype(np.int32)})
    # train_plans=1 + no replanning pins every arm to the same DP-best
    # plan: the arms must differ ONLY in the trace knob, or plan-choice
    # noise would masquerade as tracer overhead
    s = connect(trace=trace, explore_budget=0.0, train_plans=1,
                train_repeats=1, replan_factor=float("inf"))
    s.register("A", A, "columnar").register("B", B, "columnar")
    s.register("W", DenseTensor(jnp.asarray(W)), "dense_array")
    s.execute(TEXT_Q, mode="training")
    for _ in range(3):                      # jit + cache warm
        s.execute(TEXT_Q)
    return s


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    rounds = 4 if fast else 10
    per_round = 10 if fast else 40

    # ONE stack; the arms differ only in the tracer flag, flipped between
    # segments.  Two separate sessions — even identically configured, on
    # the same plan — showed a persistent few-percent p50 offset (memory
    # layout / allocator state), which would masquerade as tracer cost.
    # Per-round p50s (robust to scheduler-jitter tails) with the segment
    # order rotated every round cancel drift and order effects.
    s = make_session(False)
    tracer = s.bigdawg.tracer
    ARMS = ("tracer_off", "tracer_on", "tracer_off_b")
    round_p50 = {name: [] for name in ARMS}
    for r in range(rounds):
        for name in ARMS[r % 3:] + ARMS[:r % 3]:
            tracer.enabled = name == "tracer_on"
            lats_ms, results, _ = timed_loop(
                lambda: s.execute(TEXT_Q), per_round)
            assert all(rr.report.mode == "production" for rr in results)
            round_p50[name].append(float(np.percentile(lats_ms, 50)))

    report = {}
    med = {}
    for name in ARMS:
        p50s = sorted(round_p50[name])
        p50 = p50s[len(p50s) // 2]
        med[name] = 1e3 / p50               # median-latency rps
        report[name] = {
            "requests": rounds * per_round,
            "rounds": rounds,
            "p50_ms": round(p50, 4),
            "p50_ms_min": round(p50s[0], 4),
            "p50_ms_max": round(p50s[-1], 4),
            "rps_median": round(med[name], 3),
        }

    # one more traced serve for the sample artifacts
    tracer.enabled = True
    res = s.execute(TEXT_Q)
    trace = res.trace.to_dict()
    report["tracer_on"]["spans_per_request"] = len(trace["spans"])

    off_fast = max(med["tracer_off"], med["tracer_off_b"])
    off_slow = min(med["tracer_off"], med["tracer_off_b"])
    off_noise_pct = (off_fast - off_slow) / off_fast * 100.0
    tracing_overhead_pct = (off_fast - med["tracer_on"]) / off_fast * 100.0
    report["overhead"] = {
        "off_noise_pct": round(off_noise_pct, 3),
        "tracing_overhead_pct": round(tracing_overhead_pct, 3),
        "spans_per_request": report["tracer_on"]["spans_per_request"],
    }
    if not fast:
        assert off_noise_pct < 2.0, \
            f"disabled-tracer A/A spread {off_noise_pct:.2f}% (want < 2%)"

    snap = s.metrics()
    report["sample_trace"] = trace
    report["metrics"] = {
        "counters": {k: round(v, 6)
                     for k, v in sorted(snap["counters"].items())},
        "bd_serve_latency": {k: round(v, 6) for k, v in
                             snap["histograms"]["bd.serve_latency"].items()},
    }

    print(f"# off={med['tracer_off']:.1f} rps off_b="
          f"{med['tracer_off_b']:.1f} rps on={med['tracer_on']:.1f} rps | "
          f"A/A noise={off_noise_pct:.2f}% tracing="
          f"{tracing_overhead_pct:.2f}% "
          f"spans/req={report['overhead']['spans_per_request']}",
          file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
