"""Adaptive re-planning: predicted-vs-measured cost error converging as the
monitor's feedback replaces static estimates (the §III-C loop closed end to
end).

The query pipes a *data-dependent* relational select (~30% of a standard
normal passes ``lo=0.5``) into array-island analytics.  Before any execution
the planner can only cost it from shape rules ("select output ~ input") and
a-priori throughputs; each round of execution then feeds the loop:

  * per-node timings (training, sequential) -> calibrated op/cast rates,
  * actual intermediate sizes (every run)   -> ``Monitor.measured_sizes``,
  * measured/predicted divergence           -> online re-plans (cheap DP).

Per round this emits the cost model's prediction for the served plan (under
the sizes known so far) next to the measured wall seconds.  The headline
numbers compare the *static* prediction (round 0: shape rules + defaults)
against the *final* feedback-informed prediction, both relative to measured
reality — the error must shrink.  Also reported: the select node's static
shape-rule size vs its measured size, and the number of online re-plans.

JSON schema (stdout; progress on stderr):
  rounds: [{round, predicted_s, measured_s, rel_error, replanned, cache_hit}]
  static_predicted_s, static_rel_error, final_rel_error, converged(bool)
  select_static_bytes, select_measured_bytes, replans, plan_key

Run: PYTHONPATH=src python benchmarks/fig_adaptive_replan.py [--fast]
"""
from __future__ import annotations

import json
import sys

import numpy as np
import jax.numpy as jnp

from repro.core import (BigDAWG, CostModel, DenseTensor, array, relational,
                        dp_plans, estimate_sizes, plan_cost, signature)


def build_query():
    s = relational.select("waves", column="value", lo=0.5)
    h = array.haar(s, levels=2)
    b = array.bin_hist(h, nbins=8, levels=2)
    return array.tfidf(b)


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    n, t = (32, 64) if fast else (128, 256)
    rounds_n = 4 if fast else 8

    cm = CostModel()
    cm.calibrate(n=64 if fast else 128)
    bd = BigDAWG(cost_model=cm, train_plans=4)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")

    q = build_query()
    sig = signature(q, bd.catalog)
    sel_uid = q.nodes()[0].uid               # post-order: the select is first
    static_sizes = estimate_sizes(q, bd.catalog)

    # round 0: the static world — predicted cost of the DP's top pick from
    # shape rules + calibration only, before any execution has been observed
    static_cost, static_plan = dp_plans(q, bd.catalog, max_plans=1,
                                        cost_model=cm)[0]

    bd.execute(q, mode="training")
    rounds = []
    for r in range(rounds_n):
        rep = bd.execute(q, mode="production")
        # the model's CURRENT prediction for the served plan, under the
        # sizes measured so far — this is what converges as feedback lands
        entry = bd.plan_cache[rep.sig]
        fb_sizes = estimate_sizes(q, bd.catalog,
                                  measured=bd.monitor.measured_sizes(sig))
        pred = plan_cost(q, entry.plan, bd.catalog, bd.cost_model,
                         sizes=fb_sizes)
        rel = abs(pred - rep.seconds) / max(rep.seconds, 1e-12)
        rounds.append({"round": r, "predicted_s": round(pred, 6),
                       "measured_s": round(rep.seconds, 6),
                       "rel_error": round(rel, 4),
                       "replanned": rep.replanned,
                       "cache_hit": rep.cache_hit})
        print(f"# round {r}: pred={pred:.5f}s meas={rep.seconds:.5f}s "
              f"rel_err={rel:.3f} replanned={rep.replanned}",
              file=sys.stderr, flush=True)

    measured_ref = float(np.median([x["measured_s"] for x in rounds]))
    static_rel = abs(static_cost - measured_ref) / max(measured_ref, 1e-12)
    final_rel = rounds[-1]["rel_error"]
    measured_sz = bd.monitor.measured_sizes(sig)

    report = {
        "n_nodes": len(q.nodes()),
        "rounds": rounds,
        "static_predicted_s": round(static_cost, 6),
        "static_plan_key": static_plan.key,
        "plan_key": bd.plan_cache[sig].plan.key,
        "static_rel_error": round(static_rel, 4),
        "final_rel_error": round(final_rel, 4),
        "converged": final_rel < static_rel,
        "select_static_bytes": static_sizes[sel_uid],
        "select_measured_bytes": measured_sz.get(0),
        "replans": bd.replans,
    }
    print(f"# static_rel_err={static_rel:.3f} final_rel_err={final_rel:.3f} "
          f"select {static_sizes[sel_uid]:.0f}B -> "
          f"{measured_sz.get(0, float('nan')):.0f}B measured",
          file=sys.stderr, flush=True)
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
