"""Paper §II anecdote: dense matrix multiply in the array engine vs the
relational join-aggregate formulation (PostGRES took 166 min vs SciDB 5 s on
1000x1000; we reproduce the orders-of-magnitude gap at reduced scale)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import DenseTensor, ENGINES
from repro.core import cast as castmod
from benchmarks.common import bench, row


def main():
    print("# matmul: name,us_per_call,derived", flush=True)
    for n in (64, 128, 256):
        rng = np.random.default_rng(0)
        a = DenseTensor(jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)))
        b = DenseTensor(jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)))
        t_d, _ = bench(ENGINES["dense_array"].run, "matmul", {}, a, b)
        ca, cb = castmod.cast(a, "columnar"), castmod.cast(b, "columnar")
        t_c, _ = bench(ENGINES["columnar"].run, "matmul", {}, ca, cb,
                       warmup=0, iters=1)
        row(f"matmul.dense_array.n{n}", t_d * 1e6)
        row(f"matmul.columnar_join.n{n}", t_c * 1e6,
            f"{t_c / t_d:.0f}x slower than dense")


if __name__ == "__main__":
    main()
