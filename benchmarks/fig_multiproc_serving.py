"""Multi-process serving: requests/sec through a ``ProcPool`` of 1/2/4
worker processes on warm signature traffic, plus sharded scatter–gather
vs single-worker execution and a worker-kill recovery probe (ISSUE 7
tentpole figure).

The in-process serving stack (fig_concurrent_serving) overlaps only where
engine ops release the GIL; every pure-Python step — planning, signature
hashing, plan-cache lookups, merges — serializes client threads.  The pool
breaks that ceiling by fanning requests across N interpreters, each a full
middleware stack sharing plans through the monitor/plan-cache files.

Entries:

  * ``warm_procsK``      — S pre-trained signatures, R requests admitted
                           from a fixed 4-thread client through
                           ``QueryServer(pool)``; ``rps_speedup_vs_1`` is
                           the headline.  Process scaling needs processor
                           scaling: on a host with >=4 CPUs the 4-worker
                           pool must clear 2x the 1-worker rps (asserted);
                           on smaller hosts the numbers are recorded as
                           measured — ``host_cpus`` says which regime a
                           checked-in JSON came from, and the CI gate reads
                           it before judging the speedup.
  * ``scatter_vs_single`` — one row-range sharded sort executed as per-shard
                           fragments + k-way merge (``scatter="always"``)
                           vs whole on one worker (``"never"``), results
                           compared for equality.
  * ``fault_recovery``   — SIGKILL a worker mid-request; every request must
                           still serve (respawn + retry), zero lost.

Run: PYTHONPATH=src python benchmarks/fig_multiproc_serving.py [--fast]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import ColumnarTable, DenseTensor, array, relational
from repro.core.procpool import ProcPool
from repro.runtime.fault import WorkerKillInjector
from repro.runtime.server import QueryServer

HOST_CPUS = os.cpu_count() or 1
CLIENT_THREADS = 4
N_SIGS = 4


def make_data(rows: int):
    rng = np.random.default_rng(7)
    return {
        "T": ColumnarTable({"key": rng.integers(0, 64, rows).astype(np.int32),
                            "value": rng.normal(size=rows).astype(np.float32)}),
        "U": ColumnarTable({"key": np.arange(64, dtype=np.int32),
                            "w": rng.normal(size=64).astype(np.float32)}),
        "M": DenseTensor(rng.normal(size=(rows // 64, 16)).astype(np.float32)),
        "W": DenseTensor(rng.normal(size=(16, 8)).astype(np.float32)),
    }


def register_all(target, data):
    target.register("T", data["T"], "columnar")
    target.register("U", data["U"], "columnar")
    target.register("M", data["M"], "dense_array")
    target.register("W", data["W"], "dense_array")


def query(i: int):
    return [
        lambda: relational.sort("T", by="value"),
        lambda: relational.groupby_sum("T", key="key", value="value",
                                       num_groups=64),
        lambda: relational.join("T", "U", left_on="key", right_on="key"),
        lambda: array.matmul("M", "W"),
    ][i % N_SIGS]()


def traffic(requests: int):
    return [query(i) for i in range(requests)]


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    rows = 20_000 if fast else 120_000
    requests = 12 if fast else 48
    shard_rows = 60_000 if fast else 400_000
    proc_counts = (1, 2) if fast else (1, 2, 4)

    data = make_data(rows)
    report = {}

    with tempfile.TemporaryDirectory() as tmp:
        state = os.path.join(tmp, "monitor.json")

        # -- warm serving at 1/2/4 worker processes -------------------------
        base_rps = None
        for procs in proc_counts:
            pool = ProcPool(procs, state_path=state, train_plans=2,
                            train_repeats=1)
            try:
                register_all(pool, data)
                srv = QueryServer(pool)
                # first pool trains (persisting each signature as it goes);
                # later pools start warm from the shared files — but every
                # WORKER must serve warm, so round the warmup over the pool
                srv.warm([query(i) for i in range(N_SIGS)])
                srv.submit_many(traffic(2 * procs * N_SIGS),
                                workers=CLIENT_THREADS)      # per-worker warm
                stats0 = srv.stats()         # metrics snapshot, pre-round
                t0 = time.perf_counter()
                reps = srv.submit_many(traffic(requests),
                                       workers=CLIENT_THREADS)
                wall = time.perf_counter() - t0
                stats1 = srv.stats()
                assert all(r.mode == "production" for r in reps), \
                    "warm round hit a training serve"
                # the measured round's per-request serve time, re-derived
                # from the metrics registry rather than a hand-kept dict:
                # server.seconds sums per-request wall across client
                # threads, so dividing the delta by the request delta gives
                # mean in-request latency (> wall/requests under overlap)
                served = stats1["requests"] - stats0["requests"]
                serve_s = stats1["seconds"] - stats0["seconds"]
                lat = srv.metrics.histogram("server.latency").summary()
            finally:
                pool.close()
            rps = len(reps) / max(wall, 1e-9)
            if base_rps is None:
                base_rps = rps
            report[f"warm_procs{procs}"] = {
                "processes": procs,
                "client_threads": CLIENT_THREADS,
                "requests": served,
                "seconds": round(wall, 6),
                "rps": round(rps, 3),
                "rps_speedup_vs_1": round(rps / base_rps, 3),
                "mean_request_ms": round(serve_s / max(served, 1) * 1e3, 3),
                "p95_request_ms": round(lat["p95"] * 1e3, 3),
                "host_cpus": HOST_CPUS,
            }
            e = report[f"warm_procs{procs}"]
            print(f"# warm procs={procs} requests={e['requests']} "
                  f"rps={e['rps']:.2f} speedup={e['rps_speedup_vs_1']:.2f}x "
                  f"mean={e['mean_request_ms']:.2f}ms",
                  file=sys.stderr, flush=True)

        # process scaling needs processor scaling — only judged where the
        # host can physically deliver it
        if HOST_CPUS >= 4 and "warm_procs4" in report:
            sp = report["warm_procs4"]["rps_speedup_vs_1"]
            assert sp >= 2.0, \
                f"4-worker pool only {sp:.2f}x vs 1 on a {HOST_CPUS}-CPU host"

    # -- sharded scatter–gather vs single-worker ----------------------------
    rng = np.random.default_rng(11)
    big = ColumnarTable(
        {"key": rng.integers(0, 64, shard_rows).astype(np.int32),
         "value": rng.normal(size=shard_rows).astype(np.float32)})
    procs = min(2 if fast else 4, max(proc_counts))
    pool = ProcPool(procs, train_plans=2, train_repeats=1, scatter="never")
    try:
        pool.register("B", big, "columnar", shards=procs)
        q = relational.sort("B", by="value")
        single_rep = pool.execute(q, mode="training")
        t0 = time.perf_counter()
        single_rep = pool.execute(q)
        single_s = time.perf_counter() - t0
        pool.scatter = "always"
        scat_rep = pool.execute(q, mode="training")
        t0 = time.perf_counter()
        scat_rep = pool.execute(q)
        scat_s = time.perf_counter() - t0
        matches = bool(np.allclose(
            np.asarray(scat_rep.result.columns["value"]),
            np.asarray(single_rep.result.columns["value"])))
        assert matches, "scatter-gather result diverged from single-worker"
        assert scat_rep.shards == procs
    finally:
        pool.close()
    report["scatter_vs_single"] = {
        "processes": procs,
        "shards": procs,
        "rows": shard_rows,
        "seconds": round(scat_s, 6),
        "seconds_single": round(single_s, 6),
        "speedup_vs_single": round(single_s / max(scat_s, 1e-9), 3),
        "matches_single_worker": matches,
        "host_cpus": HOST_CPUS,
    }
    e = report["scatter_vs_single"]
    print(f"# scatter shards={e['shards']} rows={e['rows']} "
          f"scatter={e['seconds']:.3f}s single={e['seconds_single']:.3f}s "
          f"speedup={e['speedup_vs_single']:.2f}x matches={matches}",
          file=sys.stderr, flush=True)

    # -- worker-kill recovery: zero lost requests ---------------------------
    inj = WorkerKillInjector(kill_on_dispatch=2)
    pool = ProcPool(2, train_plans=2, train_repeats=1, retries=1,
                    kill_injector=inj)
    served = 0
    t0 = time.perf_counter()
    try:
        register_all(pool, data)
        kill_requests = 6
        for i in range(kill_requests):
            rep = pool.execute(query(i))
            served += 1 if rep.result is not None else 0
    finally:
        fault_wall = time.perf_counter() - t0
        # respawn/dispatch accounting lives in the pool's metrics registry
        # (pool.respawns is a view over it)
        kills = inj.kills
        respawns = int(pool.metrics.value("pool.respawns"))
        dispatches = int(pool.metrics.value("pool.dispatches"))
        trips = pool.breaker_trips
        pool.close()
    assert kills >= 1 and respawns >= 1 and served == kill_requests
    report["fault_recovery"] = {
        "requests": kill_requests,
        "served": served,
        "kills": kills,
        "respawns": respawns,
        "dispatches": dispatches,
        "breaker_trips": trips,
        "seconds": round(fault_wall, 6),
        "host_cpus": HOST_CPUS,
    }
    e = report["fault_recovery"]
    print(f"# fault kills={e['kills']} respawns={e['respawns']} "
          f"served={e['served']}/{e['requests']}",
          file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
