"""Resilient serving: bounded latency through an injected mid-serve outage,
with ZERO failed requests (ISSUE 6 tentpole).

One portable analytic signature (``tfidf(haar(waves))`` — every node has
>= 2 candidate engines) is served through a ``QueryServer`` over a
middleware constructed with a ``core.health.EngineHealth`` registry and an
``EngineFaultInjector``, across four phases:

  * ``healthy``     — the baseline: incumbent plan out of the signature
                      cache, p50/p99 anchor latencies.
  * ``engine_down`` — every engine of the incumbent plan is failed via the
                      injector.  The first request burns the breaker's
                      failure threshold in fast ``EngineDown`` retries, the
                      breaker opens, and the request is re-planned around
                      the dead engines (cheap k=1 DP, cached under the
                      mask-suffixed signature) — EVERY request still
                      succeeds, and steady-state degraded latency stays
                      within 5x the healthy p99 (asserted).
  * ``recovery``    — the injector recovers, the cooldown elapses, and the
                      half-open probe request restores the pre-failure
                      incumbent plan VERBATIM (asserted: masked serves were
                      recorded under the masked signature, so the unmasked
                      history still names the incumbent).
  * ``straggler``   — the incumbent engines are made pathologically slow
                      instead of dead: the per-engine straggler detector
                      (z-score over node times) flags them, the flags count
                      as breaker failures, the breaker trips (asserted) and
                      traffic fails over to the fast engines — a silently
                      slow engine is handled like a crashed one.

Every phase entry reports ``requests / failed / p50_ms / p99_ms /
p99_vs_healthy / failovers / breaker_trips / degraded_serves /
incumbent_serves``; ``failed`` is asserted 0 everywhere.

Run: PYTHONPATH=src python benchmarks/fig_resilient_serving.py [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax.numpy as jnp

from common import timed_loop
from repro.core import BigDAWG, DenseTensor, array
from repro.core.health import EngineHealth
from repro.core.middleware import _plan_from_key
from repro.runtime import EngineFaultInjector, QueryServer

FAILURE_THRESHOLD = 2


def query():
    return array.tfidf(array.haar("waves", levels=2))


def make_stack(cooldown_s: float, waves_shape):
    inj = EngineFaultInjector()
    # straggler_min_s: node times on this workload are a few ms with tiny
    # variance, so scheduler jitter alone can carry a huge z-score — only
    # flag slowness that actually matters at serving scale (the injected
    # 50 ms sleeps are well above the floor, jitter is well below)
    health = EngineHealth(failure_threshold=FAILURE_THRESHOLD,
                          cooldown_s=cooldown_s, straggler_min_s=0.03,
                          injector=inj)
    bd = BigDAWG(train_plans=4, train_repeats=1, health=health,
                 replan_factor=float("inf"))   # isolate failover from replan
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=waves_shape).astype(np.float32))),
        engine="dense_array")
    return bd, health, inj


def run_phase(srv: QueryServer, n: int, incumbent: str):
    """Serve ``n`` requests sequentially, timing each; a raised exception
    counts as a failed request (the tentpole's contract is that none is).
    The phase counters are deltas between metrics snapshots (``srv.stats``
    is a view over the server's Metrics registry)."""
    stats0 = srv.stats()
    lats_ms, reports, failed = timed_loop(
        lambda: srv.submit(query()), n,
        on_error=lambda exc: print(
            f"# FAILED request: {type(exc).__name__}: {exc}",
            file=sys.stderr, flush=True))
    stats1 = srv.stats()
    return {
        "requests": n,
        "failed": failed,
        "p50_ms": round(float(np.percentile(lats_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lats_ms, 99)), 3),
        "p99_vs_healthy": 0.0,                      # stamped by main()
        "failovers": stats1["failovers"] - stats0["failovers"],
        "breaker_trips": stats1["breaker_trips"] - stats0["breaker_trips"],
        "degraded_serves": stats1["degraded"] - stats0["degraded"],
        "incumbent_serves": sum(1 for r in reports
                                if r.plan_key == incumbent),
    }, reports, lats_ms


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    n = 12 if fast else 40
    cooldown_s = 0.2 if fast else 0.5
    waves_shape = (16, 64) if fast else (48, 128)

    bd, health, inj = make_stack(cooldown_s, waves_shape)
    srv = QueryServer(bd)
    srv.warm([query()])
    for _ in range(4):                   # jit warmup off the measured phases
        srv.submit(query())
    # anchor the baseline only once the measured re-ranker has settled: the
    # first few production serves re-rank on means of n=1..2 samples, and a
    # near-tied plan can briefly win (ordinary adaptation, see the recovery
    # phase note) — wait for 3 consecutive serves on the same plan
    streak, incumbent = 0, None
    for _ in range(24):
        key = srv.submit(query()).plan_key
        streak = streak + 1 if key == incumbent else 1
        incumbent = key
        if streak >= 3:
            break
    down = sorted({eng for _, eng in _plan_from_key(incumbent).assignment})

    report = {}

    # -- healthy baseline ----------------------------------------------------
    report["healthy"], _, _ = run_phase(srv, n, incumbent)
    report["healthy"]["p99_vs_healthy"] = 1.0
    healthy_p99 = report["healthy"]["p99_ms"]
    assert report["healthy"]["incumbent_serves"] == n

    # -- outage: incumbent engines down mid-serve ----------------------------
    for eng in down:
        inj.fail_engine(eng)
    report["engine_down"], reps, lats = run_phase(srv, n, incumbent)
    e = report["engine_down"]
    assert e["failed"] == 0, "requests failed during the outage"
    assert e["failovers"] >= FAILURE_THRESHOLD    # threshold burned, then
    assert e["breaker_trips"] == len(down)        # breaker open + re-plan
    assert e["incumbent_serves"] == 0
    assert all(r.status == "degraded" for r in reps)
    # steady-state degraded latency (mask-keyed cache hits; skip the first
    # request, which pays the EngineDown retries + the one masked DP)
    steady = lats[1:]
    e["p99_vs_healthy"] = round(
        float(np.percentile(steady, 99)) / max(healthy_p99, 1e-9), 3)
    assert e["p99_vs_healthy"] < 5.0, \
        f"degraded p99 {e['p99_vs_healthy']}x healthy (want < 5x)"

    # -- recovery: cooldown elapses, half-open probe restores the incumbent --
    for eng in down:
        inj.recover(eng)
    time.sleep(cooldown_s * 1.5)
    report["recovery"], reps, _ = run_phase(srv, n, incumbent)
    e = report["recovery"]
    assert e["failed"] == 0 and e["breaker_trips"] == 0
    # the hard contract: the half-open probe request itself comes back on
    # the pre-failure incumbent (masked serves never polluted the unmasked
    # history).  Later serves are the monitor's business again — ordinary
    # adaptation may promote a near-tied plan, and that is a feature
    assert reps[0].plan_key == incumbent, "probe did not restore incumbent"
    assert all(r.status == "ok" for r in reps)
    e["p99_vs_healthy"] = round(e["p99_ms"] / max(healthy_p99, 1e-9), 3)

    # -- straggler: the currently-served engines slow instead of dead --------
    # (slow whatever plan traffic actually runs on NOW — post-recovery
    # adaptation may have promoted a near-tied plan off the incumbent)
    slowed = sorted({eng for _, eng in
                     _plan_from_key(reps[-1].plan_key).assignment})
    for eng in slowed:
        inj.slow_engine(eng, 0.05)
    # pin the monitor for this phase: one slow sample is enough for the
    # measured re-ranker to route off the slow plan (ordinary adaptation),
    # which would starve the detector of its second flag and mask the
    # detector -> breaker path this phase exists to prove — the same
    # isolation as replan_factor above
    record, bd.monitor.record = bd.monitor.record, lambda *a, **k: None
    try:
        report["straggler"], reps, _ = run_phase(srv, n, incumbent)
    finally:
        bd.monitor.record = record
    e = report["straggler"]
    assert e["failed"] == 0
    assert e["breaker_trips"] >= 1, "straggler never tripped the breaker"
    # once tripped, traffic runs off the slow engines again
    assert reps[-1].status == "degraded"
    assert not ({eng for _, eng in
                 _plan_from_key(reps[-1].plan_key).assignment} & set(slowed))
    e["p99_vs_healthy"] = round(e["p99_ms"] / max(healthy_p99, 1e-9), 3)

    total_failed = sum(report[p]["failed"] for p in report)
    print(f"# zero-failure contract: {total_failed} failed requests across "
          f"{sum(report[p]['requests'] for p in report)}; "
          f"incumbent={incumbent!r} down={down}", file=sys.stderr, flush=True)
    for name, e in report.items():
        print(f"# {name}: p50={e['p50_ms']}ms p99={e['p99_ms']}ms "
              f"({e['p99_vs_healthy']}x healthy) failovers={e['failovers']} "
              f"trips={e['breaker_trips']} degraded={e['degraded_serves']}",
              file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
