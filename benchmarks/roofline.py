"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads benchmarks/artifacts/dryrun/*.json, prints per-cell terms and the
dominant bottleneck.  Run the sweep first: python -m repro.launch.sweep.
"""
from __future__ import annotations

import glob
import json
import os

ARTDIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

FIX_HINTS = {
    "collective": "raise arithmetic intensity per chip: fewer TP shards for "
                  "this size / larger per-chip batch / SP+reduce-scatter "
                  "instead of all-reduce",
    "memory": "fuse/remat less, raise accumulation microbatch, or bf16 "
              "moments to cut state traffic",
    "compute": "already MXU-bound: only kernel-level wins left (flash "
               "attention tiling, fused SSD)",
}


def load(mesh="pod_16x16"):
    short = "pod" if mesh.startswith("pod") else "multipod"
    rows, seen = [], set()
    for p in sorted(glob.glob(os.path.join(ARTDIR, "*.json"))):
        r = json.load(open(p))
        if r.get("mesh", "") not in (mesh, short):
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        rows.append(r)
    return rows


def fmt_row(r):
    if not r.get("applicable", True):
        return (f"{r['arch']:22s} {r['shape']:12s} SKIP "
                f"({r['skip_reason'][:60]}...)")
    rf = r.get("roofline")
    if rf is None:
        return f"{r['arch']:22s} {r['shape']:12s} (memory-only cell)"
    return (f"{r['arch']:22s} {r['shape']:12s} "
            f"comp={rf['t_compute']:9.4f}s mem={rf['t_memory']:9.4f}s "
            f"coll={rf['t_collective']:9.4f}s dom={rf['dominant']:10s} "
            f"useful={rf['useful_flops_ratio']:.3f} "
            f"rooffrac={rf['roofline_fraction']:.4f} "
            f"hbm={r['hbm_bytes_per_device']/1e9:5.1f}GB "
            f"fits={r['fits_16g']}")


def main():
    print("# roofline: single-pod 16x16 (256 chips), v5e constants")
    print("# name,us_per_call,derived")
    for r in load("pod_16x16"):
        print(fmt_row(r))
        rf = r.get("roofline")
        if rf:
            dom = rf["dominant"]
            us = max(rf["t_compute"], rf["t_memory"], rf["t_collective"]) * 1e6
            print(f"roofline.{r['arch']}.{r['shape']},{us:.1f},"
                  f"dom={dom};frac={rf['roofline_fraction']:.4f};"
                  f"fix={FIX_HINTS[dom][:48]}")
    print("\n# multipod fits-proof (2x16x16, 512 chips)")
    for r in load("multipod_2x16x16"):
        if not r.get("applicable", True):
            continue
        if "memory" in r:
            print(f"multipod.{r['arch']}.{r['shape']},"
                  f"{r['memory']['compile_s']*1e6:.0f},"
                  f"hbm={r['hbm_bytes_per_device']/1e9:.2f}GB;"
                  f"fits={r['fits_16g']}")


if __name__ == "__main__":
    main()
