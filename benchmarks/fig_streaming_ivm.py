"""Streaming island + incremental view maintenance: measure the warm
re-serve win from patching a materialized view with a delta fragment after
a small append, instead of recomputing the full query (ISSUE 9 tentpole;
core/deltaplan.py + the middleware view slot).

A full recompute pays the whole base every serve — ``matmul(S, W)`` over
all N rows — even when only a handful of rows arrived since the last serve.
The delta path runs the derived update fragment over JUST the appended
suffix (chain rule: ``delta @ W``), concatenates it onto the materialized
view, and serves the patched view: work proportional to the delta, not the
base.

Three entries:

  warm_reserve          — median warm serve seconds after a small append
      (delta_rows << base_rows), incremental vs full recompute over the
      same appends on an identical twin.  Both paths are checked
      element-wise equal against a fresh recompute every iteration, so the
      speedup is never bought with wrong answers.  Emits ``full_s`` /
      ``incremental_s`` / ``speedup`` / ``ivm_serves``.
  gate_small_delta      — ``incremental=True`` (the cost-model gate, NOT
      forced): after a small append the gate must pick the delta path
      (``Report.incremental`` true).
  gate_delta_dominates  — same knob, but the append dwarfs the base while
      the cached full-serve prediction stays tiny: patching cannot beat
      recomputing, so the gate must fall back (``Report.incremental``
      false, ``ivm_fallbacks`` > 0).

In full mode (not ``--fast``) the warm_reserve entry must clear >= 5x —
the tentpole's acceptance bar — and both gate directions are asserted in
every mode (they are decisions, not timings: shrinking sizes does not
excuse a wrong decision).

Run: PYTHONPATH=src python benchmarks/fig_streaming_ivm.py [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import BigDAWG, DenseTensor, Ref, array

SPEEDUP_BAR = 5.0


def _mk(rng, rows, cols):
    return DenseTensor(rng.normal(size=(rows, cols)).astype(np.float32))


def _serve_with_append(bd, q, delta, iters):
    """Median production-serve seconds, appending ``delta`` rows before
    each serve (the steady streaming state: a trickle arrives, the client
    re-asks)."""
    times, last = [], None
    for _ in range(iters):
        bd.append("S", delta)
        t0 = time.perf_counter()
        last = bd.execute(q, mode="production")
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], last


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    iters = 3 if fast else 9
    base_rows, cols, out_cols = (256, 64, 8) if fast else (4096, 256, 32)
    delta_rows = 4 if fast else 16

    rng = np.random.default_rng(0)
    base = _mk(rng, base_rows, cols)
    W = _mk(rng, cols, out_cols)
    deltas = [_mk(rng, delta_rows, cols) for _ in range(iters)]
    q = array.matmul(Ref("S"), Ref("W"))

    def fresh(incremental):
        bd = BigDAWG(train_plans=1, train_repeats=1,
                     incremental=incremental)
        # RSS creep (jit caches growing across iterations) would trip the
        # monitor's environment-drift retraining mid-run, dropping the view
        # and poisoning the medians; drift adaptation has its own benchmark
        # (fig_adaptive_replan) — pin it off to isolate the IVM effect
        bd.monitor.DRIFT_THRESHOLD = float("inf")
        bd.register("W", W, "dense_array")
        bd.register("S", base, "dense_array", streaming=True)
        bd.execute(q, mode="training")
        return bd

    # -- warm re-serve: delta patch vs full recompute, same append stream --
    bd_ivm, bd_full = fresh("force"), fresh(False)
    t_ivm = t_full = 0.0
    for i, d in enumerate(deltas):
        bd_ivm.append("S", d)
        bd_full.append("S", d)
        t0 = time.perf_counter()
        r_ivm = bd_ivm.execute(q, mode="production")
        t1 = time.perf_counter()
        r_full = bd_full.execute(q, mode="production")
        t2 = time.perf_counter()
        if i == iters // 2:              # one representative steady sample
            t_ivm, t_full = t1 - t0, t2 - t1
        assert r_ivm.incremental and not r_full.incremental
        # never buy the speedup with a wrong answer: both paths must match
        # a from-scratch recompute of the grown table
        oracle = np.asarray(bd_full.catalog["S"].obj.data) @ \
            np.asarray(W.data)
        for r in (r_ivm, r_full):
            np.testing.assert_allclose(np.asarray(r.result.data), oracle,
                                       rtol=1e-3, atol=1e-3)
    # medians over the same appends, steady state (tables already grown)
    t_ivm, _ = _serve_with_append(bd_ivm, q, deltas[0], iters)
    t_full, _ = _serve_with_append(bd_full, q, deltas[0], iters)
    speedup = t_full / max(t_ivm, 1e-9)
    warm = {
        "base_rows": base_rows, "cols": cols, "out_cols": out_cols,
        "delta_rows": delta_rows, "iters": iters,
        "full_s": round(t_full, 6), "incremental_s": round(t_ivm, 6),
        "speedup": round(speedup, 3),
        "ivm_serves": bd_ivm.ivm_serves, "ivm_fallbacks": bd_ivm.ivm_fallbacks,
    }
    print(f"# warm_reserve base={base_rows}x{cols} delta={delta_rows} "
          f"full={t_full:.6f}s incremental={t_ivm:.6f}s "
          f"speedup={speedup:.1f}x", file=sys.stderr, flush=True)
    assert bd_ivm.ivm_serves >= iters and bd_ivm.ivm_fallbacks == 0
    if not fast:
        assert speedup >= SPEEDUP_BAR, \
            f"warm re-serve speedup {speedup:.2f}x < {SPEEDUP_BAR}x"

    # -- the gate, small-delta direction: patching wins --------------------
    bd = fresh(True)
    bd.append("S", deltas[0])
    rep = bd.execute(q, mode="production")
    gate_small = {"base_rows": base_rows, "delta_rows": delta_rows,
                  "incremental": bool(rep.incremental),
                  "ivm_serves": bd.ivm_serves,
                  "ivm_fallbacks": bd.ivm_fallbacks}
    print(f"# gate_small_delta -> incremental={rep.incremental}",
          file=sys.stderr, flush=True)
    assert rep.incremental, "gate refused a clearly-profitable small delta"

    # -- the gate, dominating-delta direction: recompute wins --------------
    small_rows = 8
    bd = BigDAWG(train_plans=1, train_repeats=1, incremental=True)
    bd.monitor.DRIFT_THRESHOLD = float("inf")
    bd.register("W", W, "dense_array")
    bd.register("S", _mk(rng, small_rows, cols), "dense_array",
                streaming=True)
    bd.execute(q, mode="training")
    big = _mk(rng, max(64 * small_rows, base_rows), cols)
    bd.append("S", big)
    rep = bd.execute(q, mode="production")
    gate_big = {"base_rows": small_rows,
                "delta_rows": int(big.data.shape[0]),
                "incremental": bool(rep.incremental),
                "ivm_serves": bd.ivm_serves,
                "ivm_fallbacks": bd.ivm_fallbacks}
    print(f"# gate_delta_dominates -> incremental={rep.incremental} "
          f"fallbacks={bd.ivm_fallbacks}", file=sys.stderr, flush=True)
    assert not rep.incremental and bd.ivm_fallbacks >= 1, \
        "gate patched a delta that dwarfs the base"

    report = {"warm_reserve": warm, "gate_small_delta": gate_small,
              "gate_delta_dominates": gate_big}
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
