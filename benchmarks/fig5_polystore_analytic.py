"""Paper Fig. 5 / §IV-B: the hemodynamic-deterioration analytic
(Haar -> per-scale histograms -> TF-IDF -> kNN) under three placements:

  dense-only   (the SciDB degenerate island run)
  columnar-only(the Myria degenerate island run)
  hybrid       (Haar on the array engine, histogram+TF-IDF on the columnar
                engine, kNN back on the array engine — casts in between)

Claim reproduced: the hybrid placement beats both single-engine runs, and the
training phase discovers it automatically.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import DenseTensor, connect, execute_plan
from repro.core.planner import Plan
from repro.data import mimic_like_dataset
from repro.kernels.ref import haar_ref
from benchmarks.common import bench, row

LEVELS, NBINS, K = 6, 32, 11


def build_query(session):
    arr = session.islands.array
    coeffs = arr.haar("waves", levels=LEVELS)
    hist = arr.bin_hist(coeffs, nbins=NBINS, levels=LEVELS)
    w = arr.tfidf(hist)
    return arr.knn(w, "test_hist", k=K)


def make_session(n_patients=600, n_samples=16384):
    ds = mimic_like_dataset(n_patients + 1, n_samples)
    waves = np.asarray(ds["waveforms"].data)
    s = connect(train_plans=36)
    s.register("waves", DenseTensor(jnp.asarray(waves[:-1])),
               engine="dense_array")
    # the test patient's tf-idf-ready histogram (computed once, dense path)
    c = haar_ref(jnp.asarray(waves[-1:]), LEVELS)
    from repro.core.engines import _da_bin_hist
    th = _da_bin_hist({"nbins": NBINS, "levels": LEVELS},
                      DenseTensor(c)).data
    s.register("test_hist", DenseTensor(th), engine="dense_array")
    return s, ds["labels"]


def named_plans(q):
    """dense-only / columnar-only / hybrid assignments (post-order: haar,
    bin_hist, tfidf, knn)."""
    return {
        "dense_only": Plan(((0, "dense_array"), (1, "dense_array"),
                            (2, "dense_array"), (3, "dense_array"))),
        "columnar_only": Plan(((0, "columnar"), (1, "columnar"),
                               (2, "columnar"), (3, "columnar"))),
        "hybrid": Plan(((0, "dense_array"), (1, "columnar"),
                        (2, "columnar"), (3, "dense_array"))),
    }


def main(n_patients: int = 600, n_samples: int = 16384):
    print("# fig5: name,us_per_call,derived", flush=True)
    s, labels = make_session(n_patients, n_samples)
    q = build_query(s)
    times = {}
    for name, plan in named_plans(q).items():
        t, res = bench(lambda p=plan: execute_plan(q, p, s.catalog),
                       warmup=1, iters=3)
        times[name] = t
        row(f"fig5.{name}", t * 1e6)
    hybrid_wins = times["hybrid"] < min(times["dense_only"],
                                        times["columnar_only"])
    row("fig5.hybrid_speedup", 0.0,
        f"vs dense {times['dense_only']/times['hybrid']:.2f}x; "
        f"vs columnar {times['columnar_only']/times['hybrid']:.2f}x; "
        f"hybrid_wins={hybrid_wins}")

    # training phase should discover a plan at least as good as our named ones
    res = s.execute(q, mode="training")
    row("fig5.training_winner", res.seconds * 1e6, res.plan_key)
    res2 = s.execute(q, mode="production")
    row("fig5.production", res2.seconds * 1e6, res2.plan_key)
    return times


if __name__ == "__main__":
    main()
