"""Validate a benchmark's JSON output against its required keys — the CI
smoke gate for the fig_*.py scripts (see docs/BENCHMARKS.md).

Usage:
    python benchmarks/check_json.py FILE --require key [key ...]
    python benchmarks/check_json.py FILE --per-entry key [key ...]

``--require`` checks top-level keys; ``--per-entry`` checks that every value
of the top-level object carries the given keys (for reports keyed by test
case, like fig_planner_scaling's per-DAG entries).  Exits non-zero, naming
every missing key, if the schema does not hold — so a benchmark that
silently stops emitting a field fails the build instead of rotting.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("file")
    ap.add_argument("--require", nargs="+", default=[],
                    help="top-level keys that must be present")
    ap.add_argument("--per-entry", nargs="+", default=[],
                    help="keys every top-level entry must carry")
    args = ap.parse_args(argv)

    try:
        with open(args.file) as f:
            blob = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"check_json: {args.file}: unreadable JSON: {exc}",
              file=sys.stderr)
        return 1
    if not isinstance(blob, dict):
        print(f"check_json: {args.file}: top level is not an object",
              file=sys.stderr)
        return 1

    problems = []
    for key in args.require:
        if key not in blob:
            problems.append(f"missing top-level key {key!r}")
    if args.per_entry:
        if not blob:
            problems.append("no entries to check --per-entry keys against")
        for name, entry in blob.items():
            if not isinstance(entry, dict):
                problems.append(f"entry {name!r} is not an object")
                continue
            for key in args.per_entry:
                if key not in entry:
                    problems.append(f"entry {name!r} missing key {key!r}")

    if problems:
        for p in problems:
            print(f"check_json: {args.file}: {p}", file=sys.stderr)
        return 1
    print(f"check_json: {args.file}: ok "
          f"({len(blob)} top-level keys)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
