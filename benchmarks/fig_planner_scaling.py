"""Planner scaling: seed truncated-product enumeration vs the calibrated
container-DP planner on 6-12-node cross-island DAGs.

The seed planner took the first 16 combos of a raw ``itertools.product`` over
per-node candidates — biased toward the first node's choices and blind to
most of the space on DAGs with more than a couple of multi-engine nodes.  The
DP covers the full container-assignment space with a calibrated cost model.

For each DAG this emits (as JSON):
  * the assignment-space size and how much of it each planner considered,
  * planning wall time,
  * measured latency of each planner's best plan (the seed's best is the
    fastest of everything it could see; the DP's is its single top pick,
    reported both sequential and with concurrent level dispatch).

Run: PYTHONPATH=src python benchmarks/fig_planner_scaling.py [--fast]
"""
from __future__ import annotations

import itertools
import json
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (BigDAWG, CostModel, DenseTensor, array, relational,
                        dp_plans, execute_plan, plan_containers)
from repro.core.planner import Plan, node_candidates


# -- the seed planner, preserved for comparison -----------------------------

def seed_truncated_plans(query, catalog, max_plans=16):
    """The pre-DP enumerator: per-node product, first ``max_plans`` combos."""
    nodes = query.nodes()
    per_node = [list(node_candidates(n)) for n in nodes]
    plans = []
    for combo in itertools.product(*per_node):
        plans.append(Plan(tuple((i, e) for i, e in enumerate(combo))))
        if len(plans) >= max_plans:
            break
    return plans


# -- workload DAGs -----------------------------------------------------------

def build_dags():
    def pipeline(nbins=8, levels=2, with_hist=True):
        s = relational.select("waves", column="value", lo=0.0)
        h = array.haar(s, levels=levels)
        x = array.bin_hist(h, nbins=nbins, levels=levels) if with_hist else h
        return array.tfidf(x)

    dag6 = array.knn(array.scale(pipeline(), factor=2.0), "probe",
                     k=4)                                         # 6 nodes
    dag8 = array.matmul(pipeline(with_hist=False),
                        array.transpose(pipeline(with_hist=False)))  # 8 nodes
    dag12 = array.haar(
        array.scale(
            array.matmul(pipeline(), array.transpose(pipeline())),
            factor=0.5),
        levels=1)                                                 # 12 nodes
    return {"dag6": dag6, "dag8": dag8, "dag12": dag12}


def measure(query, plan, catalog, iters, concurrent=False):
    execute_plan(query, plan, catalog, concurrent=concurrent)     # warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        execute_plan(query, plan, catalog, concurrent=concurrent)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def main(fast: bool = False):
    fast = fast or "--fast" in sys.argv
    iters = 1 if fast else 5
    n, t = (16, 64) if fast else (64, 256)

    rng = np.random.default_rng(0)
    cm = CostModel()
    cm.calibrate(n=64 if fast else 128)
    bd = BigDAWG(cost_model=cm)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    width = 8 * 3                  # bin_hist output: nbins * (levels + 1)
    bd.register("probe", DenseTensor(jnp.asarray(
        rng.normal(size=(1, width)).astype(np.float32))), engine="dense_array")

    report = {}
    for name, q in build_dags().items():
        containers = plan_containers(q, bd.catalog)
        space = 1
        for c in containers:
            space *= len(c.candidates)

        t0 = time.perf_counter()
        seed_plans = seed_truncated_plans(q, bd.catalog)
        t_seed_plan = time.perf_counter() - t0

        t0 = time.perf_counter()
        dp = dp_plans(q, bd.catalog, max_plans=16, cost_model=cm)
        t_dp_plan = time.perf_counter() - t0

        # each planner gets the same 16-trial training budget; its "best" is
        # the fastest measured plan among what it proposed (paper §III-C-3:
        # the monitor picks among the planner's candidates by measurement)
        seed_best = min(measure(q, p, bd.catalog, iters) for p in seed_plans)
        dp_measured = [measure(q, p, bd.catalog, iters) for _, p in dp]
        dp_top1 = dp_measured[0]
        dp_chosen = min(dp_measured)
        dp_conc = measure(q, dp[dp_measured.index(dp_chosen)][1], bd.catalog,
                          iters, concurrent=True)

        report[name] = {
            "n_nodes": len(q.nodes()),
            "n_containers": len(containers),
            "assignment_space": space,
            "seed_considered": len(seed_plans),
            "dp_considered": space,          # k-best DP spans the full space
            "seed_planning_ms": round(t_seed_plan * 1e3, 3),
            "dp_planning_ms": round(t_dp_plan * 1e3, 3),
            "dp_predicted_s": round(dp[0][0], 6),
            "seed_best_measured_s": round(seed_best, 6),
            "dp_top1_measured_s": round(dp_top1, 6),
            "dp_chosen_measured_s": round(dp_chosen, 6),
            "dp_chosen_concurrent_s": round(dp_conc, 6),
            "dp_vs_seed_speedup": round(seed_best / max(dp_chosen, 1e-9), 3),
        }
        print(f"# {name}: space={space} seed_saw={len(seed_plans)} "
              f"seed_best={seed_best:.4f}s dp_chosen={dp_chosen:.4f}s",
              file=sys.stderr, flush=True)

    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
